//! Analytic kernel cost descriptors — what the hybrid-CPU simulator charges
//! a core for executing a slice of a kernel's parallel dimension.

use crate::cpu::Isa;

/// Kernel identity: the paper's CPU runtime keeps one performance-ratio
/// row per (kernel class, ISA) pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum KernelClass {
    /// int8 GEMM (prefill projections / FFN)
    GemmI8,
    /// fused Q4_0 dequant GEMV / matmul (decode projections / FFN)
    GemvQ4,
    /// multi-head attention over the KV cache
    Attention,
    /// RMSNorm
    Norm,
    /// RoPE
    Rope,
    /// SwiGLU / residual adds
    Elementwise,
    /// tensor copy (the paper names "tensor copying" as a scheduled kernel)
    Copy,
}

impl KernelClass {
    pub const ALL: [KernelClass; 7] = [
        KernelClass::GemmI8,
        KernelClass::GemvQ4,
        KernelClass::Attention,
        KernelClass::Norm,
        KernelClass::Rope,
        KernelClass::Elementwise,
        KernelClass::Copy,
    ];

    /// Position in [`KernelClass::ALL`], as a const jump table —
    /// dense-table indexing without a linear scan (see `perf::slot`).
    #[inline]
    pub const fn index(&self) -> usize {
        match self {
            KernelClass::GemmI8 => 0,
            KernelClass::GemvQ4 => 1,
            KernelClass::Attention => 2,
            KernelClass::Norm => 3,
            KernelClass::Rope => 4,
            KernelClass::Elementwise => 5,
            KernelClass::Copy => 6,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            KernelClass::GemmI8 => "gemm_i8",
            KernelClass::GemvQ4 => "gemv_q4",
            KernelClass::Attention => "attention",
            KernelClass::Norm => "norm",
            KernelClass::Rope => "rope",
            KernelClass::Elementwise => "elementwise",
            KernelClass::Copy => "copy",
        }
    }

    /// The primary ISA the kernel's inner loop uses (paper §2.2: the ISA
    /// "primarily used for these computations is specified in the code").
    pub fn primary_isa(&self) -> Isa {
        match self {
            KernelClass::GemmI8 => Isa::AvxVnni,
            KernelClass::GemvQ4 => Isa::AvxVnni,
            KernelClass::Attention => Isa::Avx2,
            KernelClass::Norm => Isa::Avx2,
            KernelClass::Rope => Isa::Avx2,
            KernelClass::Elementwise => Isa::Avx2,
            KernelClass::Copy => Isa::Stream,
        }
    }
}

/// Cost of one kernel invocation, per unit of its parallel dimension.
///
/// The simulator charges a core processing `u` units:
///   `t = max(u · ops_per_unit / compute_rate, u · bytes_per_unit / bw)`
/// (roofline combine; `bw` comes from the contention model).
#[derive(Clone, Copy, Debug)]
pub struct WorkCost {
    pub class: KernelClass,
    pub isa: Isa,
    /// length of the parallel dimension
    pub units: usize,
    /// MAC-like ops per unit (matches the ISA's ops/cycle accounting)
    pub ops_per_unit: f64,
    /// bytes of unique memory traffic per unit
    pub bytes_per_unit: f64,
}

impl WorkCost {
    pub fn new(class: KernelClass, units: usize, ops_per_unit: f64, bytes_per_unit: f64) -> Self {
        WorkCost { class, isa: class.primary_isa(), units, ops_per_unit, bytes_per_unit }
    }

    pub fn total_ops(&self) -> f64 {
        self.units as f64 * self.ops_per_unit
    }

    pub fn total_bytes(&self) -> f64 {
        self.units as f64 * self.bytes_per_unit
    }

    /// Arithmetic intensity (ops per byte) — decides compute- vs
    /// memory-bound on a roofline.
    pub fn intensity(&self) -> f64 {
        self.ops_per_unit / self.bytes_per_unit.max(1e-12)
    }
}

// ---- canonical cost constructors for the paper's workloads ----

/// INT8 GEMM `M×K×N` split along M: per row-unit `K·N` MACs; unique bytes
/// per row ≈ K (activation row) + amortized weight traffic `K·N/M`.
pub fn gemm_i8_cost(m: usize, k: usize, n: usize) -> WorkCost {
    let ops = (k * n) as f64;
    let bytes = k as f64 + (k * n) as f64 / m as f64;
    WorkCost::new(KernelClass::GemmI8, m, ops, bytes)
}

/// Q4_0 GEMV `1×K×N` split along N (weight rows): per row `K` MACs and
/// `K/2 + scales` weight bytes (the decode phase streams the weights).
pub fn gemv_q4_cost(k: usize, n: usize) -> WorkCost {
    let ops = k as f64;
    let bytes = (k / 2) as f64 + (k / 32) as f64 * 2.0;
    WorkCost::new(KernelClass::GemvQ4, n, ops, bytes)
}

/// Q4_0 matmul `S×K×N` (prefill chunk) split along N. With `s` activation
/// rows per weight pass this is the prefill phase's GEMM class: its
/// arithmetic intensity grows with the chunk length, so it must not share
/// a learned ratio row with the memory-bound µs-scale decode GEMV.
pub fn qmatmul_cost(s: usize, k: usize, n: usize) -> WorkCost {
    let ops = (s * k) as f64;
    let bytes = (k / 2) as f64 + (k / 32) as f64 * 2.0 + (s * k) as f64 * 4.0 / n as f64;
    WorkCost::new(KernelClass::GemmI8, n, ops, bytes)
}

/// Decode attention over `h` heads, `t` cached positions, head dim `dh`:
/// per head ≈ 2·t·dh MACs, reading 2·t·dh·4 bytes of KV cache.
pub fn attention_decode_cost(h: usize, t: usize, dh: usize) -> WorkCost {
    let ops = 2.0 * (t * dh) as f64;
    let bytes = 2.0 * (t * dh * 4) as f64;
    WorkCost::new(KernelClass::Attention, h, ops, bytes)
}

/// Batched prefill attention over `s` new positions × `h` heads (one
/// kernel per layer instead of one per position): unit `(si, head)`
/// attends to `t0 + si + 1` cached positions, so ops/bytes per unit use
/// the mean attended length across the chunk.
pub fn attention_prefill_cost(s: usize, h: usize, t0: usize, dh: usize) -> WorkCost {
    let t_mean = t0 as f64 + (s as f64 + 1.0) / 2.0;
    let ops = 2.0 * t_mean * dh as f64;
    let bytes = 2.0 * t_mean * (dh * 4) as f64;
    WorkCost::new(KernelClass::Attention, s * h, ops, bytes)
}

/// Elementwise over `n` scalars (grain: 1 unit = 1 kiB chunk of f32s).
pub fn elementwise_cost(n: usize, ops_per_elem: f64, streams: f64) -> WorkCost {
    let elems_per_unit = 256.0;
    let units = n.div_ceil(256);
    WorkCost::new(
        KernelClass::Elementwise,
        units,
        ops_per_elem * elems_per_unit,
        streams * 4.0 * elems_per_unit,
    )
}

/// Pure copy of `bytes` (split in 4 kiB units).
pub fn copy_cost(bytes: usize) -> WorkCost {
    let units = bytes.div_ceil(4096);
    WorkCost::new(KernelClass::Copy, units, 0.0, 4096.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primary_isa_assignments() {
        assert_eq!(KernelClass::GemmI8.primary_isa(), Isa::AvxVnni);
        assert_eq!(KernelClass::Copy.primary_isa(), Isa::Stream);
        assert_eq!(KernelClass::Norm.primary_isa(), Isa::Avx2);
    }

    #[test]
    fn gemm_cost_totals() {
        let c = gemm_i8_cost(1024, 4096, 4096);
        assert_eq!(c.units, 1024);
        // total MACs = M·K·N
        assert!((c.total_ops() - (1024f64 * 4096.0 * 4096.0)).abs() < 1.0);
        // compute-bound: intensity far above any CPU's ops/byte balance
        assert!(c.intensity() > 100.0);
    }

    #[test]
    fn gemv_cost_is_memory_bound() {
        let c = gemv_q4_cost(4096, 4096);
        // 4096 rows × (2048 + 256) bytes = 9 MiB of weights
        assert!((c.total_bytes() - 4096.0 * 2304.0).abs() < 1.0);
        // ~1.8 ops/byte → memory-bound on every CPU we model
        assert!(c.intensity() < 4.0);
    }

    #[test]
    fn attention_cost_scales_with_t() {
        let a = attention_decode_cost(32, 128, 128);
        let b = attention_decode_cost(32, 256, 128);
        assert!((b.total_ops() / a.total_ops() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn copy_cost_has_no_compute() {
        let c = copy_cost(1 << 20);
        assert_eq!(c.total_ops(), 0.0);
        assert_eq!(c.units, 256);
    }

    #[test]
    fn prefill_and_decode_matmuls_are_distinct_classes() {
        // phase-disaggregated routing steers prefill by the GEMM row and
        // decode by the GEMV row — the two constructors must not collide
        assert_eq!(qmatmul_cost(16, 2048, 2048).class, KernelClass::GemmI8);
        assert_eq!(gemv_q4_cost(2048, 2048).class, KernelClass::GemvQ4);
        // chunked prefill is markedly more compute-dense than decode
        let pf = qmatmul_cost(16, 2048, 2048);
        let dc = gemv_q4_cost(2048, 2048);
        assert!(pf.intensity() > 4.0 * dc.intensity());
    }

    #[test]
    fn class_names_unique() {
        let mut names: Vec<_> = KernelClass::ALL.iter().map(|c| c.name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), KernelClass::ALL.len());
    }
}
