//! Cost-only ("phantom") model execution at paper scale.
//!
//! llama2-7B weights don't fit this sandbox, but the *schedule* of kernel
//! invocations and their analytic costs do — which is all the simulator
//! needs to regenerate Figure 3. A [`PhantomSystem`] describes one of the
//! paper's three systems (llama.cpp, Neural Speed + OpenMP, Neural Speed +
//! dynamic); calibration notes live in DESIGN.md.

use crate::exec::{Executor, ParallelRuntime, PhantomWork};
use crate::kernels::{cost, WorkCost};
use crate::metrics::PhaseMetrics;
use crate::model::ModelConfig;

/// Efficiency knobs distinguishing the compared systems.
#[derive(Clone, Debug)]
pub struct PhantomSystem {
    pub name: String,
    /// compute efficiency of the micro-kernels relative to Neural Speed's
    /// AVX-VNNI kernels (llama.cpp ≈ 0.5, per [16] in the paper)
    pub kernel_eff: f64,
    /// achieved-bandwidth efficiency (software prefetch quality)
    pub mem_eff: f64,
}

impl PhantomSystem {
    pub fn neural_speed() -> PhantomSystem {
        PhantomSystem { name: "neural_speed".into(), kernel_eff: 1.0, mem_eff: 1.0 }
    }

    pub fn llama_cpp() -> PhantomSystem {
        PhantomSystem { name: "llama.cpp".into(), kernel_eff: 0.5, mem_eff: 0.9 }
    }

    fn scale(&self, mut c: WorkCost) -> WorkCost {
        c.ops_per_unit /= self.kernel_eff;
        c.bytes_per_unit /= self.mem_eff;
        c
    }
}

/// The per-layer kernel schedule of one decoded token at position `pos`.
pub fn decode_invocations(cfg: &ModelConfig, sys: &PhantomSystem, pos: usize) -> Vec<WorkCost> {
    let d = cfg.d_model;
    let ff = cfg.d_ff;
    let mut out = Vec::with_capacity(cfg.n_layers * 8 + 1);
    for _ in 0..cfg.n_layers {
        out.push(sys.scale(cost::gemv_q4_cost(d, d))); // wq
        out.push(sys.scale(cost::gemv_q4_cost(d, d))); // wk
        out.push(sys.scale(cost::gemv_q4_cost(d, d))); // wv
        out.push(sys.scale(cost::attention_decode_cost(cfg.n_heads, pos + 1, cfg.head_dim())));
        out.push(sys.scale(cost::gemv_q4_cost(d, d))); // wo
        out.push(sys.scale(cost::gemv_q4_cost(d, ff))); // w1
        out.push(sys.scale(cost::gemv_q4_cost(d, ff))); // w3
        out.push(sys.scale(cost::gemv_q4_cost(ff, d))); // w2
    }
    out.push(sys.scale(cost::gemv_q4_cost(d, cfg.vocab))); // lm_head
    out
}

/// The kernel schedule of a prefill over `s` prompt tokens (the paper's
/// INT8-GEMM compute path: dynamic-quantized activations × int8 weights).
pub fn prefill_invocations(cfg: &ModelConfig, sys: &PhantomSystem, s: usize) -> Vec<WorkCost> {
    let d = cfg.d_model;
    let ff = cfg.d_ff;
    let mut out = Vec::with_capacity(cfg.n_layers * 8 + 1);
    for _ in 0..cfg.n_layers {
        out.push(sys.scale(cost::gemm_i8_cost(s, d, d))); // wq
        out.push(sys.scale(cost::gemm_i8_cost(s, d, d))); // wk
        out.push(sys.scale(cost::gemm_i8_cost(s, d, d))); // wv
        // causal attention ≈ s·(s+1)/2 score+mix MACs per head-dim pair;
        // modelled as one Avx2 kernel over heads. MHA_OVERHEAD folds in the
        // non-MAC work (softmax exp, masking, transposes) that makes the
        // paper's *unscheduled* MHA a substantial share of prefill time —
        // the stated reason model-level gains (20–30 %) are below
        // kernel-level gains (65–85 %).
        let t_avg = s.div_ceil(2);
        out.push(sys.scale(WorkCost::new(
            crate::kernels::KernelClass::Attention,
            cfg.n_heads,
            MHA_OVERHEAD * 2.0 * (s * t_avg * cfg.head_dim()) as f64,
            (s * t_avg * cfg.head_dim() * 8) as f64 / cfg.n_heads as f64,
        )));
        out.push(sys.scale(cost::gemm_i8_cost(s, d, d))); // wo
        out.push(sys.scale(cost::gemm_i8_cost(s, d, ff))); // w1
        out.push(sys.scale(cost::gemm_i8_cost(s, d, ff))); // w3
        out.push(sys.scale(cost::gemm_i8_cost(s, ff, d))); // w2
    }
    out.push(sys.scale(cost::gemm_i8_cost(1, d, cfg.vocab))); // lm_head (last tok)
    out
}

/// Non-MAC overhead factor of the unoptimized multi-head-attention kernel
/// (softmax exponentials, masking, layout shuffles) relative to its MAC
/// count. Calibrated so the model-level prefill gain lands in the paper's
/// 20–30 % band while the kernel-level GEMM gain stays at 65–85 %.
pub const MHA_OVERHEAD: f64 = 8.0;

/// Run one kernel invocation the way the paper's integration does:
/// GEMM/GEMV kernels go through the dynamic-parallel loop; **attention is
/// always statically split** ("we only apply our method to GEMM kernels.
/// Other kernels, like multi-head attention, do not benefit").
fn run_one<E: Executor>(rt: &mut ParallelRuntime<E>, c: WorkCost) -> f64 {
    if c.class == crate::kernels::KernelClass::Attention {
        use crate::sched::Scheduler;
        let n = rt.exec.n_workers();
        let plan = crate::sched::StaticEven.plan(c.units, 1, &vec![1.0; n]);
        rt.exec.execute(&PhantomWork::new(c), &plan).wall_secs
    } else {
        rt.run(&PhantomWork::new(c)).wall_secs
    }
}

/// Run a full phantom generation through a runtime: prefill of
/// `prompt_len` tokens then `n_decode` decode steps. Returns phase timing
/// (virtual seconds for sim executors).
pub fn run_phantom_generation<E: Executor>(
    rt: &mut ParallelRuntime<E>,
    cfg: &ModelConfig,
    sys: &PhantomSystem,
    prompt_len: usize,
    n_decode: usize,
) -> PhaseMetrics {
    let mut m = PhaseMetrics {
        prompt_tokens: prompt_len,
        decoded_tokens: n_decode,
        ..Default::default()
    };
    for c in prefill_invocations(cfg, sys, prompt_len) {
        m.prefill_secs += run_one(rt, c);
    }
    for step in 0..n_decode {
        for c in decode_invocations(cfg, sys, prompt_len + step) {
            m.decode_secs += run_one(rt, c);
        }
    }
    m
}

/// Total Q4_0 weight bytes streamed per decode step (the paper's GEMV
/// bandwidth accounting counts weight traffic only).
pub fn decode_bytes_per_token(cfg: &ModelConfig) -> f64 {
    decode_invocations(cfg, &PhantomSystem::neural_speed(), 0)
        .iter()
        .filter(|c| c.class == crate::kernels::KernelClass::GemvQ4)
        .map(|c| c.total_bytes())
        .sum()
}

/// All decode-step bytes (weights + KV-cache attention traffic) at a
/// given position — the number that bounds long-context tokens/s.
pub fn decode_total_bytes_at(cfg: &ModelConfig, pos: usize) -> f64 {
    decode_invocations(cfg, &PhantomSystem::neural_speed(), pos)
        .iter()
        .map(|c| c.total_bytes())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::presets;
    use crate::perf::PerfConfig;
    use crate::sched::scheduler_by_name;
    use crate::sim::{SimConfig, SimExecutor};

    fn rt(preset: &str, sched: &str) -> ParallelRuntime<SimExecutor> {
        let spec = presets::preset_by_name(preset).unwrap();
        ParallelRuntime::new(
            SimExecutor::new(spec, SimConfig::noiseless()),
            scheduler_by_name(sched).unwrap(),
            PerfConfig::default(),
        )
    }

    #[test]
    fn decode_schedule_has_expected_shape() {
        let cfg = ModelConfig::llama2_7b();
        let inv = decode_invocations(&cfg, &PhantomSystem::neural_speed(), 0);
        assert_eq!(inv.len(), 32 * 8 + 1);
        // weight bytes per token ≈ 3.7 GB
        let gb = decode_bytes_per_token(&cfg) / 1e9;
        assert!((3.3..4.0).contains(&gb), "gb={gb}");
    }

    #[test]
    fn phantom_7b_decode_speed_is_paper_scale() {
        // paper: ~16 tokens/s on both testbeds after the method converges
        let cfg = ModelConfig::llama2_7b();
        let mut r = rt("ultra_125h", "dynamic");
        // warm the table with a few steps, then measure
        let _ = run_phantom_generation(&mut r, &cfg, &PhantomSystem::neural_speed(), 8, 4);
        let m = run_phantom_generation(&mut r, &cfg, &PhantomSystem::neural_speed(), 8, 8);
        let tps = m.decode_tokens_per_sec();
        assert!((10.0..25.0).contains(&tps), "tokens/s = {tps}");
    }

    #[test]
    fn dynamic_beats_static_on_prefill() {
        let cfg = ModelConfig::llama2_7b();
        let sys = PhantomSystem::neural_speed();
        let mut rd = rt("core_12900k", "dynamic");
        let _ = run_phantom_generation(&mut rd, &cfg, &sys, 64, 0); // warm table
        let md = run_phantom_generation(&mut rd, &cfg, &sys, 64, 0);
        let mut rs = rt("core_12900k", "static");
        let ms = run_phantom_generation(&mut rs, &cfg, &sys, 64, 0);
        let speedup = ms.prefill_secs / md.prefill_secs;
        assert!(speedup > 1.5, "prefill speedup {speedup}");
    }

    #[test]
    fn llama_cpp_system_is_slower() {
        let cfg = ModelConfig::llama2_7b();
        // prompt must be long enough that the GEMMs are compute-bound
        // (the paper uses 1024; 256 keeps the test fast)
        let mut r1 = rt("core_12900k", "dynamic");
        let _ = run_phantom_generation(&mut r1, &cfg, &PhantomSystem::neural_speed(), 256, 0);
        let ns = run_phantom_generation(&mut r1, &cfg, &PhantomSystem::neural_speed(), 256, 0);
        let mut r2 = rt("core_12900k", "static");
        let lc = run_phantom_generation(&mut r2, &cfg, &PhantomSystem::llama_cpp(), 256, 0);
        let ratio = lc.prefill_secs / ns.prefill_secs;
        // paper headline: up to 3.7× vs llama.cpp
        assert!((3.0..4.3).contains(&ratio), "ratio={ratio}");
    }
}
