//! The inference engine: every matmul / attention kernel of the llama
//! forward pass dispatched through the paper's dynamic-parallel loop
//! ([`ParallelRuntime`]): query ratio table → proportional partition →
//! execute on cores → measure per-core times → update table.
//!
//! Generic over the executor, so the *same* engine runs on the real
//! core-bound thread pool and on the simulated hybrid CPU.
//!
//! The host path is allocation-free at steady state: all activations,
//! quantized rows, block sums, attention scores and dequant rows live in
//! a persistent per-engine [`Scratch`] arena that grows to the model's
//! working set once and is then only borrowed. Fused dispatch
//! ([`EngineOpts::fused`]) additionally collapses QKV, gate/up and the
//! per-position prefill attention into single scheduled kernels, cutting
//! the dispatch count per decoded token from `8·L + 1` to `5·L + 1`.

pub mod phantom;

use std::ops::Range;
use std::sync::Arc;

use crate::exec::{Executor, FnWork, ParallelRuntime, SharedSlice};
use crate::kernels::{attention, cost, elementwise, gemv_q4, rope};
use crate::metrics::PhaseMetrics;
use crate::model::{argmax, ModelConfig, ModelWeights, Session};
use crate::perf::PerfConfig;
use crate::quant::{quantize_q8_dynamic_into, MatQ4, QuantizedRow};
use crate::sched::Scheduler;

/// Engine knobs.
#[derive(Clone, Copy, Debug)]
pub struct EngineOpts {
    /// use the integer (q8 activation × q4 weight) GEMV for decode — the
    /// paper's VNNI path. `false` keeps the f32 path, which is bit-exact
    /// with the serial oracle and the PJRT artifact.
    pub int_gemv: bool,
    /// partition grain (rows) for matmul kernels
    pub grain: usize,
    /// fuse QKV / gate-up projections and batch prefill attention into
    /// single scheduled kernels. Token streams are bit-identical either
    /// way (each output row is computed by the same serial code in the
    /// same accumulation order); only the dispatch count changes.
    pub fused: bool,
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts { int_gemv: false, grain: 1, fused: true }
    }
}

/// Kernel-shared scratch: quantized activation row + per-block sums,
/// computed once per GEMV on the leader instead of once per worker.
#[derive(Default)]
pub struct KernScratch {
    xsums_f: Vec<f32>,
    xq: QuantizedRow,
    xsums_i: Vec<i32>,
}

/// Persistent per-engine scratch arena. Every buffer the forward pass
/// needs is resized (never reallocated at steady state) and borrowed;
/// worker-indexed slabs give each pool worker a private window so no
/// kernel closure ever allocates.
#[derive(Default)]
pub struct Scratch {
    // decode activations
    x: Vec<f32>,
    xa: Vec<f32>,
    qkv: Vec<f32>,
    attn: Vec<f32>,
    proj: Vec<f32>,
    xf: Vec<f32>,
    gateup: Vec<f32>,
    act: Vec<f32>,
    logits: Vec<f32>,
    // kernel-shared
    kern: KernScratch,
    /// attention score slab: one `t_max` window per worker
    score_slab: Vec<f32>,
    /// qmatmul dequant slab: one `max(d, d_ff)` row window per worker
    deq_slab: Vec<f32>,
    // prefill chunk activations (sized to the largest chunk seen)
    xs: Vec<f32>,
    pxa: Vec<f32>,
    pq: Vec<f32>,
    pk: Vec<f32>,
    pv: Vec<f32>,
    pattn: Vec<f32>,
    pproj: Vec<f32>,
    pxf: Vec<f32>,
    pgate: Vec<f32>,
    pup: Vec<f32>,
    pact: Vec<f32>,
    /// transposed qmatmul output, `[N_stacked, S]`
    out_t: Vec<f32>,
}

impl Scratch {
    /// Total heap capacity held by the arena, in bytes — the leak/reset
    /// invariant: steady-state inference must not grow this.
    pub fn footprint_bytes(&self) -> usize {
        let f32s = [
            &self.x, &self.xa, &self.qkv, &self.attn, &self.proj, &self.xf, &self.gateup,
            &self.act, &self.logits, &self.kern.xsums_f, &self.score_slab, &self.deq_slab,
            &self.xs, &self.pxa, &self.pq, &self.pk, &self.pv, &self.pattn, &self.pproj,
            &self.pxf, &self.pgate, &self.pup, &self.pact, &self.out_t,
        ];
        f32s.iter().map(|v| v.capacity() * 4).sum::<usize>()
            + self.kern.xsums_i.capacity() * 4
            + self.kern.xq.q.capacity()
    }
}

/// Transpose segment `seg` (rows `seg·n .. (seg+1)·n`) of a stacked
/// `[N_stacked, s]` qmatmul output into row-major `[s, n]`.
fn transpose_seg(out_t: &[f32], n: usize, s: usize, seg: usize, dst: &mut [f32]) {
    let base = seg * n * s;
    for nn in 0..n {
        for si in 0..s {
            dst[si * n + nn] = out_t[base + nn * s + si];
        }
    }
}

pub struct Engine<E: Executor> {
    pub cfg: ModelConfig,
    pub weights: Arc<ModelWeights>,
    pub rt: ParallelRuntime<E>,
    pub opts: EngineOpts,
    /// accumulated kernel time (virtual for sim executors, wall for host)
    pub kernel_secs: f64,
    /// accumulated unique kernel memory traffic in bytes (mirrors
    /// `kernel_secs`; together they give achieved GB/s)
    pub bytes_moved: f64,
    scratch: Scratch,
    /// per-worker GEMV row-tile widths derived from the executor's core
    /// classes (P=4, E=2, LPE=1)
    tiles: Vec<usize>,
    n_workers: usize,
}

impl<E: Executor> Engine<E> {
    pub fn new(
        cfg: ModelConfig,
        weights: Arc<ModelWeights>,
        exec: E,
        sched: Box<dyn Scheduler>,
        perf_cfg: PerfConfig,
    ) -> Engine<E> {
        cfg.validate().expect("invalid model config");
        let tiles: Vec<usize> = exec.core_kinds().iter().map(|&k| gemv_q4::tile_for(k)).collect();
        let n_workers = exec.n_workers();
        Engine {
            cfg,
            weights,
            rt: ParallelRuntime::new(exec, sched, perf_cfg),
            opts: EngineOpts::default(),
            kernel_secs: 0.0,
            bytes_moved: 0.0,
            scratch: Scratch::default(),
            tiles,
            n_workers,
        }
    }

    pub fn new_session(&self) -> Session {
        Session::new(&self.cfg)
    }

    /// Arena heap footprint (see [`Scratch::footprint_bytes`]).
    pub fn scratch_footprint_bytes(&self) -> usize {
        self.scratch.footprint_bytes()
    }

    // ---- scheduled kernels ----

    /// GEMV over row-stacked matrices (all sharing `x`) through the
    /// dynamic-parallel loop; `y` is the full stacked output. Block sums
    /// (and on the int path the q8 row) are computed once here, not per
    /// worker; workers run the core-class-tiled microkernel.
    fn gemv_multi(&mut self, ws: &[&MatQ4], x: &[f32], y: &mut [f32], kern: &mut KernScratch) {
        let k = ws[0].cols;
        let n_total: usize = ws.iter().map(|w| w.rows).sum();
        debug_assert_eq!(y.len(), n_total);
        let c = cost::gemv_q4_cost(k, n_total);
        let tiles = &self.tiles;
        let (wall, bytes) = {
            let shared = SharedSlice::new(y);
            if self.opts.int_gemv {
                quantize_q8_dynamic_into(x, &mut kern.xq);
                gemv_q4::block_sums_i32_into(&kern.xq.q, &mut kern.xsums_i);
                let (xq, xscale, xsums) = (&kern.xq.q, kern.xq.scale, &kern.xsums_i);
                let work = FnWork::new(c, self.opts.grain, move |wk, r: Range<usize>| {
                    // SAFETY: scheduler ranges are disjoint
                    let out = unsafe { shared.slice_mut(r.clone()) };
                    let tile = tiles.get(wk).copied().unwrap_or(1);
                    gemv_q4::gemv_q8q4_multi_rows_pre(ws, xq, xscale, xsums, r, out, tile);
                });
                let res = self.rt.run(&work);
                (res.wall_secs, res.bytes)
            } else {
                gemv_q4::block_sums_f32_into(x, &mut kern.xsums_f);
                let xsums = &kern.xsums_f;
                let work = FnWork::new(c, self.opts.grain, move |wk, r: Range<usize>| {
                    let out = unsafe { shared.slice_mut(r.clone()) };
                    let tile = tiles.get(wk).copied().unwrap_or(1);
                    gemv_q4::gemv_q4_f32_multi_rows_pre(ws, x, xsums, r, out, tile);
                });
                let res = self.rt.run(&work);
                (res.wall_secs, res.bytes)
            }
        };
        self.kernel_secs += wall;
        self.bytes_moved += bytes;
    }

    /// Prefill matmul over row-stacked matrices (`x` is S×K), transposed
    /// output `[N_stacked, S]` into `out_t`. Dequant rows come from the
    /// per-worker `deq_slab` windows — the kernel closure never allocates.
    fn qmatmul_multi_t(
        &mut self,
        ws: &[&MatQ4],
        x: &[f32],
        s: usize,
        out_t: &mut [f32],
        deq_slab: &mut [f32],
    ) {
        let k = ws[0].cols;
        let n_total: usize = ws.iter().map(|w| w.rows).sum();
        debug_assert_eq!(out_t.len(), n_total * s);
        let kw = deq_slab.len() / self.n_workers;
        debug_assert!(kw >= k);
        let c = cost::qmatmul_cost(s, k, n_total);
        let (wall, bytes) = {
            let shared = SharedSlice::new(out_t);
            let slab = SharedSlice::new(deq_slab);
            let work = FnWork::new(c, self.opts.grain, move |wk, r: Range<usize>| {
                // SAFETY: ranges disjoint; slab windows disjoint per worker
                let out = unsafe { shared.slice_mut(r.start * s..r.end * s) };
                let scratch = unsafe { slab.slice_mut(wk * kw..wk * kw + k) };
                gemv_q4::qmatmul_f32_multi_rows_into_t(ws, x, s, r, out, scratch);
            });
            let res = self.rt.run(&work);
            (res.wall_secs, res.bytes)
        };
        self.kernel_secs += wall;
        self.bytes_moved += bytes;
    }

    /// Decode attention through the dynamic-parallel loop (heads split);
    /// `out` is the full `[h, dh]` buffer, score rows come from the
    /// per-worker `slab` windows.
    fn attention_into(
        &mut self,
        cache: &attention::KvLayer,
        q: &[f32],
        pos: usize,
        out: &mut [f32],
        slab: &mut [f32],
    ) {
        let dh = cache.dh;
        let t_cap = cache.t_max;
        let t_len = pos + 1;
        debug_assert!(slab.len() >= self.n_workers * t_cap);
        let c = cost::attention_decode_cost(cache.h, t_len, dh);
        let (wall, bytes) = {
            let out_s = SharedSlice::new(out);
            let slab_s = SharedSlice::new(slab);
            let work = FnWork::new(c, 1, move |wk, r: Range<usize>| {
                // SAFETY: head ranges disjoint; one slab window per worker
                let win = unsafe { out_s.slice_mut(r.start * dh..r.end * dh) };
                let scores = unsafe { slab_s.slice_mut(wk * t_cap..wk * t_cap + t_len) };
                attention::attention_decode_rows_into(q, cache, pos, r, win, scores);
            });
            let res = self.rt.run(&work);
            (res.wall_secs, res.bytes)
        };
        self.kernel_secs += wall;
        self.bytes_moved += bytes;
    }

    /// Batched prefill attention: one kernel for the whole `s`-row chunk,
    /// parallel over `(position, head)` units.
    fn attention_prefill_into(
        &mut self,
        cache: &attention::KvLayer,
        q: &[f32],
        pos0: usize,
        s: usize,
        out: &mut [f32],
        slab: &mut [f32],
    ) {
        let (h, dh) = (cache.h, cache.dh);
        let t_cap = cache.t_max;
        let t_need = pos0 + s;
        debug_assert!(slab.len() >= self.n_workers * t_cap);
        let c = cost::attention_prefill_cost(s, h, pos0, dh);
        let (wall, bytes) = {
            let out_s = SharedSlice::new(out);
            let slab_s = SharedSlice::new(slab);
            let work = FnWork::new(c, 1, move |wk, r: Range<usize>| {
                // SAFETY: unit ranges disjoint; one slab window per worker
                let win = unsafe { out_s.slice_mut(r.start * dh..r.end * dh) };
                let scores = unsafe { slab_s.slice_mut(wk * t_cap..wk * t_cap + t_need) };
                attention::attention_prefill_units_into(q, cache, pos0, s, r, win, scores);
            });
            let res = self.rt.run(&work);
            (res.wall_secs, res.bytes)
        };
        self.kernel_secs += wall;
        self.bytes_moved += bytes;
    }

    // ---- model forward ----

    fn decode_step_with(&mut self, session: &mut Session, token: u32, scr: &mut Scratch) {
        let weights = Arc::clone(&self.weights);
        let d = self.cfg.d_model;
        let (h, dh) = (self.cfg.n_heads, self.cfg.head_dim());
        let d_ff = self.cfg.d_ff;
        let (eps, theta) = (self.cfg.rms_eps, self.cfg.rope_theta);
        let t_max = self.cfg.t_max;
        let vocab = weights.lm_head.rows;
        let pos = session.pos;
        assert!(pos < t_max, "KV cache exhausted");

        // grow-once arena shapes (no-ops at steady state)
        scr.x.resize(d, 0.0);
        scr.xa.resize(d, 0.0);
        scr.qkv.resize(3 * d, 0.0);
        scr.attn.resize(d, 0.0);
        scr.proj.resize(d, 0.0);
        scr.xf.resize(d, 0.0);
        scr.gateup.resize(2 * d_ff, 0.0);
        scr.act.resize(d_ff, 0.0);
        scr.logits.resize(vocab, 0.0);
        scr.score_slab.resize(self.n_workers * t_max, 0.0);

        scr.x.copy_from_slice(weights.embed.row(token as usize));
        let fused = self.opts.fused;

        for (li, layer) in weights.layers.iter().enumerate() {
            elementwise::rmsnorm(&scr.x, &layer.attn_norm, eps, &mut scr.xa);
            if fused {
                self.gemv_multi(
                    &[&layer.wq, &layer.wk, &layer.wv],
                    &scr.xa,
                    &mut scr.qkv,
                    &mut scr.kern,
                );
            } else {
                let (q, rest) = scr.qkv.split_at_mut(d);
                let (kk, vv) = rest.split_at_mut(d);
                self.gemv_multi(&[&layer.wq], &scr.xa, q, &mut scr.kern);
                self.gemv_multi(&[&layer.wk], &scr.xa, kk, &mut scr.kern);
                self.gemv_multi(&[&layer.wv], &scr.xa, vv, &mut scr.kern);
            }
            {
                let (q, rest) = scr.qkv.split_at_mut(d);
                let (kk, vv) = rest.split_at_mut(d);
                rope::rope_heads(q, h, dh, pos as i32, theta);
                rope::rope_heads(kk, h, dh, pos as i32, theta);
                let cache = &mut session.kv[li];
                for head in 0..h {
                    cache.write(
                        head,
                        pos,
                        &kk[head * dh..(head + 1) * dh],
                        &vv[head * dh..(head + 1) * dh],
                    );
                }
            }
            self.attention_into(
                &session.kv[li],
                &scr.qkv[..d],
                pos,
                &mut scr.attn,
                &mut scr.score_slab,
            );
            self.gemv_multi(&[&layer.wo], &scr.attn, &mut scr.proj, &mut scr.kern);
            elementwise::add_inplace(&mut scr.x, &scr.proj);

            elementwise::rmsnorm(&scr.x, &layer.ffn_norm, eps, &mut scr.xf);
            if fused {
                self.gemv_multi(&[&layer.w1, &layer.w3], &scr.xf, &mut scr.gateup, &mut scr.kern);
            } else {
                let (g, u) = scr.gateup.split_at_mut(d_ff);
                self.gemv_multi(&[&layer.w1], &scr.xf, g, &mut scr.kern);
                self.gemv_multi(&[&layer.w3], &scr.xf, u, &mut scr.kern);
            }
            {
                let (g, u) = scr.gateup.split_at(d_ff);
                elementwise::silu_mul(g, u, &mut scr.act);
            }
            self.gemv_multi(&[&layer.w2], &scr.act, &mut scr.proj, &mut scr.kern);
            elementwise::add_inplace(&mut scr.x, &scr.proj);
        }

        elementwise::rmsnorm(&scr.x, &weights.final_norm, eps, &mut scr.xa);
        session.pos += 1;
        self.gemv_multi(&[&weights.lm_head], &scr.xa, &mut scr.logits, &mut scr.kern);
    }

    /// One scheduled decode step into the arena — must produce exactly the
    /// logits of [`crate::model::decode_step_serial`] when `int_gemv` is
    /// off, fused or not. Returns a borrow of the arena's logits buffer;
    /// steady-state calls perform zero heap allocations.
    pub fn decode_step_in(&mut self, session: &mut Session, token: u32) -> &[f32] {
        let mut scr = std::mem::take(&mut self.scratch);
        self.decode_step_with(session, token, &mut scr);
        self.scratch = scr;
        &self.scratch.logits
    }

    /// Allocating convenience wrapper around [`Engine::decode_step_in`].
    pub fn decode_step(&mut self, session: &mut Session, token: u32) -> Vec<f32> {
        self.decode_step_in(session, token).to_vec()
    }

    fn prefill_with(&mut self, session: &mut Session, tokens: &[u32], scr: &mut Scratch) {
        let weights = Arc::clone(&self.weights);
        let s = tokens.len();
        assert!(s > 0, "empty prompt");
        let d = self.cfg.d_model;
        let (h, dh) = (self.cfg.n_heads, self.cfg.head_dim());
        let d_ff = self.cfg.d_ff;
        let (eps, theta) = (self.cfg.rms_eps, self.cfg.rope_theta);
        let t_max = self.cfg.t_max;
        assert!(session.pos + s <= t_max, "prompt exceeds KV capacity");
        let vocab = weights.lm_head.rows;
        let pos0 = session.pos;
        let fused = self.opts.fused;

        scr.xs.resize(s * d, 0.0);
        scr.pxa.resize(s * d, 0.0);
        scr.pq.resize(s * d, 0.0);
        scr.pk.resize(s * d, 0.0);
        scr.pv.resize(s * d, 0.0);
        scr.pattn.resize(s * d, 0.0);
        scr.pproj.resize(s * d, 0.0);
        scr.pxf.resize(s * d, 0.0);
        scr.pgate.resize(s * d_ff, 0.0);
        scr.pup.resize(s * d_ff, 0.0);
        scr.pact.resize(s * d_ff, 0.0);
        scr.out_t.resize(s * (3 * d).max(2 * d_ff), 0.0);
        scr.deq_slab.resize(self.n_workers * d.max(d_ff), 0.0);
        scr.score_slab.resize(self.n_workers * t_max, 0.0);
        scr.xa.resize(d, 0.0);
        scr.logits.resize(vocab, 0.0);

        for (si, &t) in tokens.iter().enumerate() {
            scr.xs[si * d..(si + 1) * d].copy_from_slice(weights.embed.row(t as usize));
        }

        for (li, layer) in weights.layers.iter().enumerate() {
            // projections, batched over the chunk
            for si in 0..s {
                let (src, dst) =
                    (&scr.xs[si * d..(si + 1) * d], &mut scr.pxa[si * d..(si + 1) * d]);
                elementwise::rmsnorm(src, &layer.attn_norm, eps, dst);
            }
            if fused {
                let (pxa, out_t) = (&scr.pxa, &mut scr.out_t[..3 * d * s]);
                self.qmatmul_multi_t(
                    &[&layer.wq, &layer.wk, &layer.wv],
                    pxa,
                    s,
                    out_t,
                    &mut scr.deq_slab,
                );
                transpose_seg(&scr.out_t, d, s, 0, &mut scr.pq);
                transpose_seg(&scr.out_t, d, s, 1, &mut scr.pk);
                transpose_seg(&scr.out_t, d, s, 2, &mut scr.pv);
            } else {
                for (w, dst) in [
                    (&layer.wq, &mut scr.pq),
                    (&layer.wk, &mut scr.pk),
                    (&layer.wv, &mut scr.pv),
                ] {
                    let (pxa, out_t) = (&scr.pxa, &mut scr.out_t[..d * s]);
                    self.qmatmul_multi_t(&[w], pxa, s, out_t, &mut scr.deq_slab);
                    transpose_seg(&scr.out_t, d, s, 0, dst);
                }
            }
            for si in 0..s {
                let p = (pos0 + si) as i32;
                rope::rope_heads(&mut scr.pq[si * d..(si + 1) * d], h, dh, p, theta);
                rope::rope_heads(&mut scr.pk[si * d..(si + 1) * d], h, dh, p, theta);
            }
            {
                let cache = &mut session.kv[li];
                for si in 0..s {
                    for head in 0..h {
                        let o = si * d + head * dh;
                        cache.write(
                            head,
                            pos0 + si,
                            &scr.pk[o..o + dh],
                            &scr.pv[o..o + dh],
                        );
                    }
                }
            }
            if fused {
                // causal attention for the whole chunk as one kernel
                let (pq, pattn) = (&scr.pq, &mut scr.pattn);
                self.attention_prefill_into(
                    &session.kv[li],
                    pq,
                    pos0,
                    s,
                    pattn,
                    &mut scr.score_slab,
                );
            } else {
                // per chunk position (heads scheduled)
                for si in 0..s {
                    let (q_si, out_si) = (
                        &scr.pq[si * d..(si + 1) * d],
                        &mut scr.pattn[si * d..(si + 1) * d],
                    );
                    self.attention_into(
                        &session.kv[li],
                        q_si,
                        pos0 + si,
                        out_si,
                        &mut scr.score_slab,
                    );
                }
            }
            {
                let (pattn, out_t) = (&scr.pattn, &mut scr.out_t[..d * s]);
                self.qmatmul_multi_t(&[&layer.wo], pattn, s, out_t, &mut scr.deq_slab);
            }
            transpose_seg(&scr.out_t, d, s, 0, &mut scr.pproj);
            elementwise::add_inplace(&mut scr.xs, &scr.pproj);

            for si in 0..s {
                let (src, dst) =
                    (&scr.xs[si * d..(si + 1) * d], &mut scr.pxf[si * d..(si + 1) * d]);
                elementwise::rmsnorm(src, &layer.ffn_norm, eps, dst);
            }
            if fused {
                let (pxf, out_t) = (&scr.pxf, &mut scr.out_t[..2 * d_ff * s]);
                self.qmatmul_multi_t(&[&layer.w1, &layer.w3], pxf, s, out_t, &mut scr.deq_slab);
                transpose_seg(&scr.out_t, d_ff, s, 0, &mut scr.pgate);
                transpose_seg(&scr.out_t, d_ff, s, 1, &mut scr.pup);
            } else {
                for (w, dst) in [(&layer.w1, &mut scr.pgate), (&layer.w3, &mut scr.pup)] {
                    let (pxf, out_t) = (&scr.pxf, &mut scr.out_t[..d_ff * s]);
                    self.qmatmul_multi_t(&[w], pxf, s, out_t, &mut scr.deq_slab);
                    transpose_seg(&scr.out_t, d_ff, s, 0, dst);
                }
            }
            elementwise::silu_mul(&scr.pgate, &scr.pup, &mut scr.pact);
            {
                let (pact, out_t) = (&scr.pact, &mut scr.out_t[..d * s]);
                self.qmatmul_multi_t(&[&layer.w2], pact, s, out_t, &mut scr.deq_slab);
            }
            transpose_seg(&scr.out_t, d, s, 0, &mut scr.pproj);
            elementwise::add_inplace(&mut scr.xs, &scr.pproj);
        }

        session.pos += s;
        elementwise::rmsnorm(&scr.xs[(s - 1) * d..], &weights.final_norm, eps, &mut scr.xa);
        self.gemv_multi(&[&weights.lm_head], &scr.xa, &mut scr.logits, &mut scr.kern);
    }

    /// Scheduled prefill of a whole prompt chunk (any length ≤ capacity)
    /// into the arena. Returns a borrow of the last token's logits;
    /// steady-state same-size chunks perform zero heap allocations.
    pub fn prefill_in(&mut self, session: &mut Session, tokens: &[u32]) -> &[f32] {
        let mut scr = std::mem::take(&mut self.scratch);
        self.prefill_with(session, tokens, &mut scr);
        self.scratch = scr;
        &self.scratch.logits
    }

    /// Allocating convenience wrapper around [`Engine::prefill_in`].
    pub fn prefill(&mut self, session: &mut Session, tokens: &[u32]) -> Vec<f32> {
        self.prefill_in(session, tokens).to_vec()
    }

    /// Full generation: prefill the prompt, then greedy-decode `n_new`
    /// tokens. Returns generated tokens + per-phase timing.
    pub fn generate(
        &mut self,
        session: &mut Session,
        prompt: &[u32],
        n_new: usize,
    ) -> (Vec<u32>, PhaseMetrics) {
        let mut metrics = PhaseMetrics {
            prompt_tokens: prompt.len(),
            decoded_tokens: 0,
            ..Default::default()
        };
        let t0 = self.kernel_secs;
        let mut next = argmax(self.prefill_in(session, prompt));
        metrics.prefill_secs = self.kernel_secs - t0;

        let mut out = Vec::with_capacity(n_new);
        let t1 = self.kernel_secs;
        for _ in 0..n_new {
            if session.remaining_capacity(&self.cfg) == 0 {
                break;
            }
            out.push(next);
            next = argmax(self.decode_step_in(session, next));
            metrics.decoded_tokens += 1;
        }
        metrics.decode_secs = self.kernel_secs - t1;
        (out, metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::presets;
    use crate::model::decode_step_serial;
    use crate::pool::HostPool;
    use crate::sched::DynamicScheduler;
    use crate::sim::{SimConfig, SimExecutor};

    fn sim_engine(n_cores_preset: &str) -> Engine<SimExecutor> {
        let cfg = ModelConfig::micro();
        let weights = Arc::new(ModelWeights::random_init(&cfg, 11));
        let spec = presets::preset_by_name(n_cores_preset).unwrap();
        let exec = SimExecutor::new(
            spec,
            SimConfig { execute_real: true, ..SimConfig::noiseless() },
        );
        Engine::new(cfg, weights, exec, Box::new(DynamicScheduler), PerfConfig::default())
    }

    #[test]
    fn scheduled_decode_matches_serial_oracle_exactly() {
        let mut e = sim_engine("ultra_125h");
        let mut s1 = e.new_session();
        let mut s2 = e.new_session();
        for (i, t) in [3u32, 9, 1, 7].iter().enumerate() {
            let scheduled = e.decode_step(&mut s1, *t);
            let serial = decode_step_serial(&e.cfg.clone(), &e.weights.clone(), &mut s2, *t);
            assert_eq!(scheduled, serial, "step {i}");
        }
    }

    #[test]
    fn unfused_decode_also_matches_serial_oracle_exactly() {
        let mut e = sim_engine("ultra_125h");
        e.opts.fused = false;
        let mut s1 = e.new_session();
        let mut s2 = e.new_session();
        for t in [3u32, 9, 1, 7] {
            let scheduled = e.decode_step(&mut s1, t);
            let serial = decode_step_serial(&e.cfg.clone(), &e.weights.clone(), &mut s2, t);
            assert_eq!(scheduled, serial);
        }
    }

    #[test]
    fn fused_and_unfused_paths_are_bit_identical() {
        let mut ef = sim_engine("core_12900k");
        let mut eu = sim_engine("core_12900k");
        eu.opts.fused = false;
        let mut sf = ef.new_session();
        let mut su = eu.new_session();
        let lf = ef.prefill(&mut sf, &[5, 2, 9, 14, 3]);
        let lu = eu.prefill(&mut su, &[5, 2, 9, 14, 3]);
        assert_eq!(lf, lu, "prefill logits");
        for (k1, k2) in sf.kv.iter().zip(&su.kv) {
            assert_eq!(k1.k, k2.k, "K caches");
            assert_eq!(k1.v, k2.v, "V caches");
        }
        let (tf, _) = ef.generate(&mut sf, &[1, 2], 6);
        let (tu, _) = eu.generate(&mut su, &[1, 2], 6);
        assert_eq!(tf, tu, "token streams");
        // fused dispatches fewer kernels → strictly less virtual time
        assert!(ef.kernel_secs < eu.kernel_secs, "{} !< {}", ef.kernel_secs, eu.kernel_secs);
    }

    #[test]
    fn prefill_matches_sequential_decode() {
        let mut e = sim_engine("core_12900k");
        let toks = [5u32, 2, 9, 14, 3, 8, 1, 0];
        let mut s1 = e.new_session();
        let lp = e.prefill(&mut s1, &toks);
        let mut s2 = e.new_session();
        let mut ld = Vec::new();
        for &t in &toks {
            ld = e.decode_step(&mut s2, t);
        }
        assert_eq!(s1.pos, s2.pos);
        for (a, b) in lp.iter().zip(&ld) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        // KV caches identical
        for (k1, k2) in s1.kv.iter().zip(&s2.kv) {
            for (a, b) in k1.k.iter().zip(&k2.k) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn generate_reports_phase_metrics() {
        let mut e = sim_engine("ultra_125h");
        let mut s = e.new_session();
        let (tokens, m) = e.generate(&mut s, &[1, 2, 3, 4], 6);
        assert_eq!(tokens.len(), 6);
        assert_eq!(m.prompt_tokens, 4);
        assert_eq!(m.decoded_tokens, 6);
        assert!(m.prefill_secs > 0.0 && m.decode_secs > 0.0);
        assert!(m.decode_tokens_per_sec() > 0.0);
    }

    #[test]
    fn generation_is_deterministic_and_executor_independent() {
        // same tokens whether simulated on 125H or 12900K (virtual timing
        // differs, computation must not)
        let mut e1 = sim_engine("ultra_125h");
        let mut e2 = sim_engine("core_12900k");
        let mut s1 = e1.new_session();
        let mut s2 = e2.new_session();
        let (t1, _) = e1.generate(&mut s1, &[1, 2, 3], 8);
        let (t2, _) = e2.generate(&mut s2, &[1, 2, 3], 8);
        assert_eq!(t1, t2);
    }

    #[test]
    fn host_pool_engine_matches_sim_engine() {
        let cfg = ModelConfig::micro();
        let weights = Arc::new(ModelWeights::random_init(&cfg, 11));
        let pool = HostPool::new(2);
        let mut host_engine = Engine::new(
            cfg,
            Arc::clone(&weights),
            pool,
            Box::new(DynamicScheduler),
            PerfConfig::default(),
        );
        let mut sim = sim_engine("ultra_125h");
        let mut sh = host_engine.new_session();
        let mut ss = sim.new_session();
        let lh = host_engine.decode_step(&mut sh, 7);
        let ls = sim.decode_step(&mut ss, 7);
        assert_eq!(lh, ls);
    }

    #[test]
    fn int_gemv_tracks_f32_path() {
        let mut e = sim_engine("ultra_125h");
        let mut ef = sim_engine("ultra_125h");
        e.opts.int_gemv = true;
        let mut s1 = e.new_session();
        let mut s2 = ef.new_session();
        let li = e.decode_step(&mut s1, 5);
        let lf = ef.decode_step(&mut s2, 5);
        let denom = lf.iter().fold(0.0f32, |a, &v| a.max(v.abs())).max(1e-3);
        for (a, b) in li.iter().zip(&lf) {
            assert!((a - b).abs() / denom < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn perf_table_learns_during_inference() {
        let mut e = sim_engine("core_12900k");
        let mut s = e.new_session();
        e.generate(&mut s, &[1, 2, 3, 4, 5, 6, 7, 8], 4);
        let rel = e
            .rt
            .relative_ratios(crate::kernels::KernelClass::GemvQ4, crate::cpu::Isa::AvxVnni)
            .unwrap();
        // P-cores must have learned a higher ratio than E-cores
        assert!(rel[0] > 1.2, "P-core ratio {rel:?}");
    }

    #[test]
    fn bytes_moved_tracks_kernel_traffic() {
        let mut e = sim_engine("ultra_125h");
        assert_eq!(e.bytes_moved, 0.0);
        let mut s = e.new_session();
        e.decode_step(&mut s, 3);
        // at least the Q4 weight bytes of one full forward pass
        let cfg = &e.cfg;
        let per_gemv = |k: usize, n: usize| (k / 2 + k / 32 * 2) as f64 * n as f64;
        let d = cfg.d_model;
        let mut floor = per_gemv(d, e.weights.lm_head.rows);
        for _ in 0..cfg.n_layers {
            floor += 4.0 * per_gemv(d, d) + 2.0 * per_gemv(d, cfg.d_ff) + per_gemv(cfg.d_ff, d);
        }
        assert!(e.bytes_moved >= floor, "{} < {floor}", e.bytes_moved);
    }

    #[test]
    fn scratch_arena_does_not_leak_across_sessions() {
        let mut e = sim_engine("ultra_125h");
        // warm up: one prefill + decode round sizes every buffer
        let mut s = e.new_session();
        e.prefill_in(&mut s, &[1, 2, 3, 4]);
        e.decode_step_in(&mut s, 5);
        let warm = e.scratch_footprint_bytes();
        assert!(warm > 0);
        for seed in 0..4u32 {
            let mut s = e.new_session();
            e.prefill_in(&mut s, &[seed, seed + 1, 1, 2]);
            for t in 0..6u32 {
                e.decode_step_in(&mut s, t % 16);
            }
            assert_eq!(e.scratch_footprint_bytes(), warm, "arena grew on session {seed}");
        }
    }
}
