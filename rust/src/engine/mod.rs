//! The inference engine: every matmul / attention kernel of the llama
//! forward pass dispatched through the paper's dynamic-parallel loop
//! ([`ParallelRuntime`]): query ratio table → proportional partition →
//! execute on cores → measure per-core times → update table.
//!
//! Generic over the executor, so the *same* engine runs on the real
//! core-bound thread pool and on the simulated hybrid CPU.

pub mod phantom;

use std::ops::Range;
use std::sync::Arc;

use crate::exec::{Executor, FnWork, ParallelRuntime, SharedSlice};
use crate::kernels::{attention, cost, elementwise, gemv_q4, rope};
use crate::metrics::PhaseMetrics;
use crate::model::{argmax, ModelConfig, ModelWeights, Session};
use crate::perf::PerfConfig;
use crate::quant::{quantize_q8_dynamic, MatQ4};
use crate::sched::Scheduler;

/// Engine knobs.
#[derive(Clone, Copy, Debug)]
pub struct EngineOpts {
    /// use the integer (q8 activation × q4 weight) GEMV for decode — the
    /// paper's VNNI path. `false` keeps the f32 path, which is bit-exact
    /// with the serial oracle and the PJRT artifact.
    pub int_gemv: bool,
    /// partition grain (rows) for matmul kernels
    pub grain: usize,
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts { int_gemv: false, grain: 1 }
    }
}

pub struct Engine<E: Executor> {
    pub cfg: ModelConfig,
    pub weights: Arc<ModelWeights>,
    pub rt: ParallelRuntime<E>,
    pub opts: EngineOpts,
    /// accumulated kernel time (virtual for sim executors, wall for host)
    pub kernel_secs: f64,
}

impl<E: Executor> Engine<E> {
    pub fn new(
        cfg: ModelConfig,
        weights: Arc<ModelWeights>,
        exec: E,
        sched: Box<dyn Scheduler>,
        perf_cfg: PerfConfig,
    ) -> Engine<E> {
        cfg.validate().expect("invalid model config");
        Engine {
            cfg,
            weights,
            rt: ParallelRuntime::new(exec, sched, perf_cfg),
            opts: EngineOpts::default(),
            kernel_secs: 0.0,
        }
    }

    pub fn new_session(&self) -> Session {
        Session::new(&self.cfg)
    }

    // ---- scheduled kernels ----

    /// GEMV through the dynamic-parallel loop.
    fn gemv(&mut self, w: &MatQ4, x: &[f32]) -> Vec<f32> {
        let n = w.rows;
        let mut y = vec![0.0f32; n];
        let c = cost::gemv_q4_cost(w.cols, n);
        let wall;
        {
            let shared = SharedSlice::new(&mut y);
            if self.opts.int_gemv {
                let xq = quantize_q8_dynamic(x);
                let work = FnWork::new(c, self.opts.grain, move |_wk, r: Range<usize>| {
                    // SAFETY: scheduler ranges are disjoint
                    let out = unsafe { shared.slice_mut(r.clone()) };
                    gemv_q4::gemv_q8q4_rows_into(w, &xq, r, out);
                });
                wall = self.rt.run(&work).wall_secs;
            } else {
                let work = FnWork::new(c, self.opts.grain, move |_wk, r: Range<usize>| {
                    let out = unsafe { shared.slice_mut(r.clone()) };
                    gemv_q4::gemv_q4_f32_rows_into(w, x, r, out);
                });
                wall = self.rt.run(&work).wall_secs;
            }
        }
        self.kernel_secs += wall;
        y
    }

    /// Prefill matmul (`x` is S×K) through the dynamic-parallel loop.
    /// Returns row-major `[S, N]`.
    fn qmatmul(&mut self, w: &MatQ4, x: &[f32], s: usize) -> Vec<f32> {
        let n = w.rows;
        let k = w.cols;
        let mut out_t = vec![0.0f32; n * s]; // transposed: worker-contiguous
        let c = cost::qmatmul_cost(s, k, n);
        {
            let shared = SharedSlice::new(&mut out_t);
            let work = FnWork::new(c, self.opts.grain, move |_wk, r: Range<usize>| {
                let out = unsafe { shared.slice_mut(r.start * s..r.end * s) };
                let mut scratch = vec![0.0f32; k];
                gemv_q4::qmatmul_f32_rows_into_t(w, x, s, r, out, &mut scratch);
            });
            self.kernel_secs += self.rt.run(&work).wall_secs;
        }
        // transpose [N, S] → [S, N]
        let mut out = vec![0.0f32; s * n];
        for nn in 0..n {
            for si in 0..s {
                out[si * n + nn] = out_t[nn * s + si];
            }
        }
        out
    }

    /// Decode attention through the dynamic-parallel loop (heads split).
    fn attention(&mut self, cache: &attention::KvLayer, q: &[f32], pos: usize) -> Vec<f32> {
        let (h, dh) = (cache.h, cache.dh);
        let mut out = vec![0.0f32; h * dh];
        let c = cost::attention_decode_cost(h, pos + 1, dh);
        {
            let shared = SharedSlice::new(&mut out);
            let work = FnWork::new(c, 1, move |_wk, r: Range<usize>| {
                let full = unsafe { shared.slice_mut(r.start * dh..r.end * dh) };
                let mut scratch = Vec::new();
                // compute heads r into the window (relative indexing)
                for (hi, head) in r.enumerate() {
                    let mut tmp = vec![0.0f32; cache.h * dh];
                    attention::attention_decode_range(
                        q,
                        cache,
                        pos,
                        &mut tmp,
                        &mut scratch,
                        head..head + 1,
                    );
                    full[hi * dh..(hi + 1) * dh].copy_from_slice(&tmp[head * dh..(head + 1) * dh]);
                }
            });
            self.kernel_secs += self.rt.run(&work).wall_secs;
        }
        out
    }

    // ---- model forward ----

    /// One scheduled decode step — must produce exactly the logits of
    /// [`crate::model::decode_step_serial`] when `int_gemv` is off.
    pub fn decode_step(&mut self, session: &mut Session, token: u32) -> Vec<f32> {
        let weights = Arc::clone(&self.weights);
        let cfg = self.cfg.clone();
        let d = cfg.d_model;
        let (h, dh) = (cfg.n_heads, cfg.head_dim());
        let pos = session.pos;
        assert!(pos < cfg.t_max, "KV cache exhausted");
        let mut x = weights.embed.row(token as usize).to_vec();

        for (li, layer) in weights.layers.iter().enumerate() {
            let mut xa = vec![0.0f32; d];
            elementwise::rmsnorm(&x, &layer.attn_norm, cfg.rms_eps, &mut xa);
            let mut q = self.gemv(&layer.wq, &xa);
            let mut k = self.gemv(&layer.wk, &xa);
            let v = self.gemv(&layer.wv, &xa);
            rope::rope_heads(&mut q, h, dh, pos as i32, cfg.rope_theta);
            rope::rope_heads(&mut k, h, dh, pos as i32, cfg.rope_theta);
            let cache = &mut session.kv[li];
            for head in 0..h {
                cache.write(
                    head,
                    pos,
                    &k[head * dh..(head + 1) * dh],
                    &v[head * dh..(head + 1) * dh],
                );
            }
            let attn = self.attention(&session.kv[li], &q, pos);
            let proj = self.gemv(&layer.wo, &attn);
            elementwise::add_inplace(&mut x, &proj);

            let mut xf = vec![0.0f32; d];
            elementwise::rmsnorm(&x, &layer.ffn_norm, cfg.rms_eps, &mut xf);
            let gate = self.gemv(&layer.w1, &xf);
            let up = self.gemv(&layer.w3, &xf);
            let mut act = vec![0.0f32; cfg.d_ff];
            elementwise::silu_mul(&gate, &up, &mut act);
            let down = self.gemv(&layer.w2, &act);
            elementwise::add_inplace(&mut x, &down);
        }

        let mut xn = vec![0.0f32; d];
        elementwise::rmsnorm(&x, &weights.final_norm, cfg.rms_eps, &mut xn);
        session.pos += 1;
        self.gemv(&weights.lm_head, &xn)
    }

    /// Scheduled prefill of a whole prompt chunk (any length ≤ capacity).
    /// Returns the last token's logits.
    pub fn prefill(&mut self, session: &mut Session, tokens: &[u32]) -> Vec<f32> {
        let weights = Arc::clone(&self.weights);
        let cfg = self.cfg.clone();
        let s = tokens.len();
        assert!(s > 0, "empty prompt");
        assert!(session.pos + s <= cfg.t_max, "prompt exceeds KV capacity");
        let d = cfg.d_model;
        let (h, dh) = (cfg.n_heads, cfg.head_dim());
        let pos0 = session.pos;

        let mut xs = vec![0.0f32; s * d];
        for (si, &t) in tokens.iter().enumerate() {
            xs[si * d..(si + 1) * d].copy_from_slice(weights.embed.row(t as usize));
        }

        for (li, layer) in weights.layers.iter().enumerate() {
            // projections, batched over the chunk
            let mut xa = vec![0.0f32; s * d];
            for si in 0..s {
                let (src, dst) = (&xs[si * d..(si + 1) * d], &mut xa[si * d..(si + 1) * d]);
                elementwise::rmsnorm(src, &layer.attn_norm, cfg.rms_eps, dst);
            }
            let mut q = self.qmatmul(&layer.wq, &xa, s);
            let mut k = self.qmatmul(&layer.wk, &xa, s);
            let v = self.qmatmul(&layer.wv, &xa, s);
            for si in 0..s {
                let p = (pos0 + si) as i32;
                rope::rope_heads(&mut q[si * d..(si + 1) * d], h, dh, p, cfg.rope_theta);
                rope::rope_heads(&mut k[si * d..(si + 1) * d], h, dh, p, cfg.rope_theta);
            }
            {
                let cache = &mut session.kv[li];
                for si in 0..s {
                    for head in 0..h {
                        let o = si * d + head * dh;
                        cache.write(head, pos0 + si, &k[o..o + dh], &v[o..o + dh]);
                    }
                }
            }
            // causal attention per chunk position (heads scheduled)
            let mut attn = vec![0.0f32; s * d];
            for si in 0..s {
                let out =
                    self.attention(&session.kv[li], &q[si * d..(si + 1) * d], pos0 + si);
                attn[si * d..(si + 1) * d].copy_from_slice(&out);
            }
            let proj = self.qmatmul(&layer.wo, &attn, s);
            elementwise::add_inplace(&mut xs, &proj);

            let mut xf = vec![0.0f32; s * d];
            for si in 0..s {
                let (src, dst) = (&xs[si * d..(si + 1) * d], &mut xf[si * d..(si + 1) * d]);
                elementwise::rmsnorm(src, &layer.ffn_norm, cfg.rms_eps, dst);
            }
            let gate = self.qmatmul(&layer.w1, &xf, s);
            let up = self.qmatmul(&layer.w3, &xf, s);
            let mut act = vec![0.0f32; s * cfg.d_ff];
            elementwise::silu_mul(&gate, &up, &mut act);
            let down = self.qmatmul(&layer.w2, &act, s);
            elementwise::add_inplace(&mut xs, &down);
        }

        session.pos += s;
        let mut xn = vec![0.0f32; d];
        elementwise::rmsnorm(&xs[(s - 1) * d..], &weights.final_norm, cfg.rms_eps, &mut xn);
        self.gemv(&weights.lm_head, &xn)
    }

    /// Full generation: prefill the prompt, then greedy-decode `n_new`
    /// tokens. Returns generated tokens + per-phase timing.
    pub fn generate(
        &mut self,
        session: &mut Session,
        prompt: &[u32],
        n_new: usize,
    ) -> (Vec<u32>, PhaseMetrics) {
        let mut metrics = PhaseMetrics {
            prompt_tokens: prompt.len(),
            decoded_tokens: 0,
            ..Default::default()
        };
        let t0 = self.kernel_secs;
        let logits = self.prefill(session, prompt);
        metrics.prefill_secs = self.kernel_secs - t0;

        let mut out = Vec::with_capacity(n_new);
        let mut next = argmax(&logits);
        let t1 = self.kernel_secs;
        for _ in 0..n_new {
            if session.remaining_capacity(&self.cfg) == 0 {
                break;
            }
            out.push(next);
            let logits = self.decode_step(session, next);
            next = argmax(&logits);
            metrics.decoded_tokens += 1;
        }
        metrics.decode_secs = self.kernel_secs - t1;
        (out, metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::presets;
    use crate::model::decode_step_serial;
    use crate::pool::HostPool;
    use crate::sched::DynamicScheduler;
    use crate::sim::{SimConfig, SimExecutor};

    fn sim_engine(n_cores_preset: &str) -> Engine<SimExecutor> {
        let cfg = ModelConfig::micro();
        let weights = Arc::new(ModelWeights::random_init(&cfg, 11));
        let spec = presets::preset_by_name(n_cores_preset).unwrap();
        let exec = SimExecutor::new(
            spec,
            SimConfig { execute_real: true, ..SimConfig::noiseless() },
        );
        Engine::new(cfg, weights, exec, Box::new(DynamicScheduler), PerfConfig::default())
    }

    #[test]
    fn scheduled_decode_matches_serial_oracle_exactly() {
        let mut e = sim_engine("ultra_125h");
        let mut s1 = e.new_session();
        let mut s2 = e.new_session();
        for (i, t) in [3u32, 9, 1, 7].iter().enumerate() {
            let scheduled = e.decode_step(&mut s1, *t);
            let serial = decode_step_serial(&e.cfg.clone(), &e.weights.clone(), &mut s2, *t);
            assert_eq!(scheduled, serial, "step {i}");
        }
    }

    #[test]
    fn prefill_matches_sequential_decode() {
        let mut e = sim_engine("core_12900k");
        let toks = [5u32, 2, 9, 14, 3, 8, 1, 0];
        let mut s1 = e.new_session();
        let lp = e.prefill(&mut s1, &toks);
        let mut s2 = e.new_session();
        let mut ld = Vec::new();
        for &t in &toks {
            ld = e.decode_step(&mut s2, t);
        }
        assert_eq!(s1.pos, s2.pos);
        for (a, b) in lp.iter().zip(&ld) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        // KV caches identical
        for (k1, k2) in s1.kv.iter().zip(&s2.kv) {
            for (a, b) in k1.k.iter().zip(&k2.k) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn generate_reports_phase_metrics() {
        let mut e = sim_engine("ultra_125h");
        let mut s = e.new_session();
        let (tokens, m) = e.generate(&mut s, &[1, 2, 3, 4], 6);
        assert_eq!(tokens.len(), 6);
        assert_eq!(m.prompt_tokens, 4);
        assert_eq!(m.decoded_tokens, 6);
        assert!(m.prefill_secs > 0.0 && m.decode_secs > 0.0);
        assert!(m.decode_tokens_per_sec() > 0.0);
    }

    #[test]
    fn generation_is_deterministic_and_executor_independent() {
        // same tokens whether simulated on 125H or 12900K (virtual timing
        // differs, computation must not)
        let mut e1 = sim_engine("ultra_125h");
        let mut e2 = sim_engine("core_12900k");
        let mut s1 = e1.new_session();
        let mut s2 = e2.new_session();
        let (t1, _) = e1.generate(&mut s1, &[1, 2, 3], 8);
        let (t2, _) = e2.generate(&mut s2, &[1, 2, 3], 8);
        assert_eq!(t1, t2);
    }

    #[test]
    fn host_pool_engine_matches_sim_engine() {
        let cfg = ModelConfig::micro();
        let weights = Arc::new(ModelWeights::random_init(&cfg, 11));
        let pool = HostPool::new(2);
        let mut host_engine = Engine::new(
            cfg,
            Arc::clone(&weights),
            pool,
            Box::new(DynamicScheduler),
            PerfConfig::default(),
        );
        let mut sim = sim_engine("ultra_125h");
        let mut sh = host_engine.new_session();
        let mut ss = sim.new_session();
        let lh = host_engine.decode_step(&mut sh, 7);
        let ls = sim.decode_step(&mut ss, 7);
        assert_eq!(lh, ls);
    }

    #[test]
    fn int_gemv_tracks_f32_path() {
        let mut e = sim_engine("ultra_125h");
        let mut ef = sim_engine("ultra_125h");
        e.opts.int_gemv = true;
        let mut s1 = e.new_session();
        let mut s2 = ef.new_session();
        let li = e.decode_step(&mut s1, 5);
        let lf = ef.decode_step(&mut s2, 5);
        let denom = lf.iter().fold(0.0f32, |a, &v| a.max(v.abs())).max(1e-3);
        for (a, b) in li.iter().zip(&lf) {
            assert!((a - b).abs() / denom < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn perf_table_learns_during_inference() {
        let mut e = sim_engine("core_12900k");
        let mut s = e.new_session();
        e.generate(&mut s, &[1, 2, 3, 4, 5, 6, 7, 8], 4);
        let rel = e
            .rt
            .relative_ratios(crate::kernels::KernelClass::GemvQ4, crate::cpu::Isa::AvxVnni)
            .unwrap();
        // P-cores must have learned a higher ratio than E-cores
        assert!(rel[0] > 1.2, "P-core ratio {rel:?}");
    }
}
