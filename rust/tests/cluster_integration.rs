//! Acceptance tier for the cluster tier, driven entirely through the
//! deterministic cluster harness (`dynpar::cluster::harness`): scripted
//! virtual-time arrivals over N simulated machines behind one admission
//! plane — no sockets, no wall-clock sleeps, bit-for-bit reproducible.
//!
//! * A whole-machine degrade mid-trace must be detected from timing alone:
//!   cluster-level skew crosses the threshold, `replace()` drains the
//!   dying machine, in-flight sessions migrate across the interconnect
//!   (charged in KV bytes), and every token stream stays bit-identical to
//!   the same trace served without the disturbance — and to a solo
//!   `Engine::generate` oracle.
//! * Re-placement must actually buy time back: the monitored run's
//!   makespan beats riding out the degrade with the monitor disabled.

use std::sync::Arc;

use dynpar::cluster::harness::{run_cluster, ClusterReport};
use dynpar::cluster::{ClusterCoordinator, InterconnectSpec, MachineId, MachineSpec};
use dynpar::cpu::{presets, CpuSpec};
use dynpar::engine::Engine;
use dynpar::model::{ModelConfig, ModelWeights};
use dynpar::perf::PerfConfig;
use dynpar::sched::DynamicScheduler;
use dynpar::router::ServingPolicy;
use dynpar::server::fleet::{DriftMonitor, EngineFactory};
use dynpar::server::protocol::Request;
use dynpar::server::testing::TraceEvent;
use dynpar::sim::{SimConfig, SimExecutor};

const WEIGHTS_SEED: u64 = 41;
const N_STREAMS: u64 = 4;
const DEGRADE_AT: f64 = 2.0e-5;
const TAIL_AT: f64 = 2.5e-5;

/// Memory bandwidth scaled far out of the way so round time tracks core
/// speed — a *compute* theft (the background load) must show up in the
/// learned rates, and the micro model's ns-scale kernels would otherwise
/// hide it behind dispatch overhead (zeroed here for the same reason).
fn compute_bound_machine() -> CpuSpec {
    let mut spec = presets::core_12900k();
    spec.name = "core_12900k_cb".into();
    for c in spec.cores.iter_mut() {
        c.mem_bw_gbps *= 50.0;
    }
    spec.bus_bw_gbps *= 50.0;
    spec
}

fn sim() -> SimConfig {
    SimConfig {
        execute_real: true,
        dispatch_overhead_secs: 0.0,
        chunk_claim_overhead_secs: 0.0,
        ..SimConfig::noiseless()
    }
}

fn factory(machine: CpuSpec) -> EngineFactory<SimExecutor> {
    let cfg = ModelConfig::micro();
    let weights = Arc::new(ModelWeights::random_init(&cfg, WEIGHTS_SEED));
    Box::new(move |lease, _dispatch| {
        let exec = lease.sim_executor(&machine, sim());
        Engine::new(
            cfg.clone(),
            Arc::clone(&weights),
            exec,
            Box::new(DynamicScheduler),
            PerfConfig::default(),
        )
    })
}

/// Two identical compute-bound machines: equal capability seeds keep the
/// healthy cluster's skew at 1.0, so any threshold crossing is the
/// injected degrade and nothing else.
fn two_machines() -> (ClusterCoordinator, Vec<EngineFactory<SimExecutor>>) {
    let cpu = compute_bound_machine();
    let specs = [
        MachineSpec::cores_only(cpu.clone()),
        MachineSpec::cores_only(cpu.clone()),
    ];
    let cluster = ClusterCoordinator::new(&specs, InterconnectSpec::default());
    (cluster, vec![factory(cpu.clone()), factory(cpu)])
}

fn req(id: u64, max_new: usize) -> Request {
    Request {
        id,
        prompt: vec![(id as u32) * 3 + 1, 7, 2, 9],
        max_new_tokens: max_new,
    }
}

/// Four streams; a warm-up wave converges the learned per-machine
/// strengths, then machine 0 loses 90% of every core and a heavy wave
/// lands on all streams.
fn degrade_trace(degrade: bool) -> Vec<TraceEvent> {
    let mut trace: Vec<TraceEvent> =
        (0..N_STREAMS).map(|s| TraceEvent::Connect { at: 0.0, stream: s }).collect();
    for id in 0..8u64 {
        trace.push(TraceEvent::arrive(1.0e-6, id % N_STREAMS, req(id, 8)));
    }
    if degrade {
        trace.push(TraceEvent::DegradeMachine { at: DEGRADE_AT, machine: 0, fraction: 0.9 });
    }
    for id in 8..20u64 {
        trace.push(TraceEvent::arrive(TAIL_AT, id % N_STREAMS, req(id, 24)));
    }
    trace
}

fn serve(monitor: DriftMonitor, degrade: bool) -> ClusterReport {
    let (cluster, factories) = two_machines();
    let policy = ServingPolicy::builder()
        .max_batch(4)
        .prefill_chunk(4)
        .queue_depth(64)
        .drift(monitor.threshold, monitor.cooldown)
        .build()
        .expect("test policy validates");
    run_cluster(cluster, &factories, &policy, degrade_trace(degrade))
}

#[test]
fn machine_degrade_triggers_replacement_with_bit_identical_streams() {
    let replaced = serve(DriftMonitor::new(1.5, 8), true);
    let stuck = serve(DriftMonitor::disabled(), true);
    let undisturbed = serve(DriftMonitor::disabled(), false);

    // the monitor fired from the serving loop with the measured skew past
    // the threshold, and the re-placement actually moved sessions across
    // the interconnect (within-machine moves would be free)
    assert_eq!(replaced.replacements, 1, "skews {:?}", replaced.cluster_skew_at_trigger);
    assert!(
        replaced.cluster_skew_at_trigger[0] > 1.5,
        "skew {:?}",
        replaced.cluster_skew_at_trigger
    );
    assert!(replaced.migrated_sessions >= 1, "no in-flight session migrated");
    assert!(replaced.interconnect_bytes > 0.0, "cross-machine migration was free");
    assert_eq!(stuck.replacements, 0);
    assert_eq!(undisturbed.replacements, 0);

    // every stream of all three runs is bit-identical: migration across
    // machines never changes a token
    assert!(replaced.all_finished() && stuck.all_finished() && undisturbed.all_finished());
    for id in 0..20u64 {
        assert!(!replaced.tokens_of(id).is_empty(), "request {id} produced nothing");
        assert_eq!(replaced.tokens_of(id), undisturbed.tokens_of(id), "request {id}");
        assert_eq!(stuck.tokens_of(id), undisturbed.tokens_of(id), "request {id}");
    }

    // ...and matches a solo oracle run outside the cluster entirely
    let cfg = ModelConfig::micro();
    let weights = Arc::new(ModelWeights::random_init(&cfg, WEIGHTS_SEED));
    let exec = SimExecutor::new(compute_bound_machine(), sim());
    let mut oracle =
        Engine::new(cfg, weights, exec, Box::new(DynamicScheduler), PerfConfig::default());
    for id in [0u64, 9, 19] {
        let r = req(id, if id < 8 { 8 } else { 24 });
        let mut s = oracle.new_session();
        let (expect, _) = oracle.generate(&mut s, &r.prompt, r.max_new_tokens);
        assert_eq!(replaced.tokens_of(id), &expect[..], "request {id} vs solo oracle");
    }

    // re-placement must buy wall time back vs riding out the degrade
    assert!(
        replaced.base.makespan < stuck.base.makespan,
        "re-placement did not recover: {} vs {}",
        replaced.base.makespan,
        stuck.base.makespan
    );

    // the dying machine drained: every stream now lives on machine 1
    for s in 0..N_STREAMS {
        let cluster_placement = replaced.final_placements.get(&s).copied();
        assert_eq!(cluster_placement, Some(MachineId(1)), "stream {s} stayed on the dead machine");
    }
}

#[test]
fn cluster_runs_are_deterministic_across_invocations() {
    let a = serve(DriftMonitor::new(1.5, 8), true);
    let b = serve(DriftMonitor::new(1.5, 8), true);
    assert_eq!(a.base.makespan, b.base.makespan, "virtual time diverged between runs");
    assert_eq!(a.replacements, b.replacements);
    assert_eq!(a.interconnect_bytes, b.interconnect_bytes);
    for id in 0..20u64 {
        assert_eq!(a.tokens_of(id), b.tokens_of(id), "request {id}");
    }
}
