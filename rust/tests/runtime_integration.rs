//! Integration: the L2/L1 PJRT path against the native engine — the
//! cross-layer parity tests that prove the three-layer stack composes.
//! All tests skip gracefully if `make artifacts` hasn't run.

use std::sync::Arc;

use dynpar::cpu::presets;
use dynpar::engine::Engine;
use dynpar::model::{ModelConfig, ModelWeights};
use dynpar::perf::PerfConfig;
use dynpar::runtime::{artifacts::default_artifact_dir, Manifest, PjrtEngine};
use dynpar::sched::DynamicScheduler;
use dynpar::sim::{SimConfig, SimExecutor};

fn manifest() -> Option<Manifest> {
    let dir = default_artifact_dir();
    if dir.join("manifest.json").exists() {
        Some(Manifest::load(dir).unwrap())
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn native_engine(cfg: &ModelConfig, weights: &Arc<ModelWeights>) -> Engine<SimExecutor> {
    let exec = SimExecutor::new(
        presets::ultra_125h(),
        SimConfig { execute_real: true, ..SimConfig::noiseless() },
    );
    Engine::new(
        cfg.clone(),
        Arc::clone(weights),
        exec,
        Box::new(DynamicScheduler),
        PerfConfig::default(),
    )
}

#[test]
fn micro_decode_logits_parity() {
    let Some(m) = manifest() else { return };
    let cfg = ModelConfig::micro();
    let weights = Arc::new(ModelWeights::random_init(&cfg, 17));
    let mut pjrt = PjrtEngine::load(&m, "micro", &weights).unwrap();
    let mut native = native_engine(&cfg, &weights);
    let mut session = native.new_session();
    for (i, t) in [5u32, 9, 100, 2].iter().enumerate() {
        let ln = native.decode_step(&mut session, *t);
        let lp = pjrt.decode_step(*t).unwrap();
        assert_eq!(ln.len(), lp.len());
        let denom = ln.iter().fold(0.0f32, |a, &v| a.max(v.abs())).max(1e-3);
        for (a, b) in ln.iter().zip(&lp) {
            assert!(
                (a - b).abs() / denom < 2e-4,
                "step {i}: native {a} vs pjrt {b} (denom {denom})"
            );
        }
    }
}

#[test]
fn micro_prefill_chunk_parity() {
    let Some(m) = manifest() else { return };
    let cfg = ModelConfig::micro();
    let weights = Arc::new(ModelWeights::random_init(&cfg, 23));
    let mut pjrt = PjrtEngine::load(&m, "micro", &weights).unwrap();
    let mut native = native_engine(&cfg, &weights);
    let prompt: Vec<u32> = (1..=cfg.prefill_len as u32).collect();
    let mut session = native.new_session();
    let ln = native.prefill(&mut session, &prompt);
    let lp = pjrt.prefill_chunk(&prompt).unwrap();
    let denom = ln.iter().fold(0.0f32, |a, &v| a.max(v.abs())).max(1e-3);
    for (a, b) in ln.iter().zip(&lp) {
        assert!((a - b).abs() / denom < 2e-4, "native {a} vs pjrt {b}");
    }
}

#[test]
fn tiny_generation_token_parity() {
    let Some(m) = manifest() else { return };
    let cfg = ModelConfig::tiny();
    let weights = Arc::new(ModelWeights::random_init(&cfg, 0));
    let mut pjrt = PjrtEngine::load(&m, "tiny", &weights).unwrap();
    let mut native = native_engine(&cfg, &weights);
    let prompt: Vec<u32> = (1..=20).collect(); // 16-chunk + 4 decode-tail
    let mut session = native.new_session();
    let (tn, _) = native.generate(&mut session, &prompt, 10);
    let tp = pjrt.generate(&prompt, 10).unwrap();
    assert_eq!(tn, tp, "generated tokens diverged");
}

#[test]
fn pjrt_engine_reset_reproduces() {
    let Some(m) = manifest() else { return };
    let cfg = ModelConfig::micro();
    let weights = Arc::new(ModelWeights::random_init(&cfg, 31));
    let mut pjrt = PjrtEngine::load(&m, "micro", &weights).unwrap();
    let a = pjrt.generate(&[1, 2, 3], 5).unwrap();
    pjrt.reset().unwrap();
    let b = pjrt.generate(&[1, 2, 3], 5).unwrap();
    assert_eq!(a, b);
}

#[test]
fn pjrt_engine_enforces_capacity() {
    let Some(m) = manifest() else { return };
    let cfg = ModelConfig::micro();
    let weights = Arc::new(ModelWeights::random_init(&cfg, 37));
    let mut pjrt = PjrtEngine::load(&m, "micro", &weights).unwrap();
    for t in 0..cfg.t_max {
        pjrt.decode_step((t % cfg.vocab) as u32).unwrap();
    }
    assert!(pjrt.decode_step(0).is_err(), "should refuse past t_max");
}

#[test]
fn prefill_chunk_arity_is_validated() {
    let Some(m) = manifest() else { return };
    let cfg = ModelConfig::micro();
    let weights = Arc::new(ModelWeights::random_init(&cfg, 41));
    let mut pjrt = PjrtEngine::load(&m, "micro", &weights).unwrap();
    assert!(pjrt.prefill_chunk(&[1, 2, 3]).is_err(), "wrong chunk size must fail");
}
