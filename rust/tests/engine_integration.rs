//! Integration: the scheduled inference engine on the tiny real model —
//! correctness against the serial oracle, phase metrics, table learning,
//! and generation workflows (the native half of the e2e driver).

use std::sync::Arc;

use dynpar::cpu::presets;
use dynpar::engine::Engine;
use dynpar::model::{decode_step_serial, ModelConfig, ModelWeights, Session};
use dynpar::perf::PerfConfig;
use dynpar::sched::scheduler_by_name;
use dynpar::sim::{SimConfig, SimExecutor};

fn engine(sched: &str) -> Engine<SimExecutor> {
    let cfg = ModelConfig::tiny();
    let weights = Arc::new(ModelWeights::random_init(&cfg, 42));
    let exec = SimExecutor::new(
        presets::ultra_125h(),
        SimConfig { execute_real: true, ..SimConfig::noiseless() },
    );
    Engine::new(cfg, weights, exec, scheduler_by_name(sched).unwrap(), PerfConfig::default())
}

#[test]
fn tiny_model_scheduled_equals_serial_over_a_sequence() {
    let mut e = engine("dynamic");
    let cfg = e.cfg.clone();
    let weights = Arc::clone(&e.weights);
    let mut s_sched = e.new_session();
    let mut s_serial = Session::new(&cfg);
    for t in [1u32, 17, 300, 42, 511, 7] {
        let a = e.decode_step(&mut s_sched, t);
        let b = decode_step_serial(&cfg, &weights, &mut s_serial, t);
        assert_eq!(a, b, "divergence at token {t}");
    }
}

#[test]
fn all_schedulers_produce_identical_logits() {
    // partitioning must never change the numbers, only the timing
    let mut reference: Option<Vec<f32>> = None;
    for sched in ["dynamic", "static", "workstealing", "guided"] {
        let mut e = engine(sched);
        let mut s = e.new_session();
        e.prefill(&mut s, &[5, 9, 2, 8]);
        let logits = e.decode_step(&mut s, 3);
        match &reference {
            None => reference = Some(logits),
            Some(r) => assert_eq!(&logits, r, "scheduler {sched} changed results"),
        }
    }
}

#[test]
fn generate_end_to_end_with_metrics() {
    let mut e = engine("dynamic");
    let prompt: Vec<u32> = (1..=24).collect();
    let mut s = e.new_session();
    let (tokens, m) = e.generate(&mut s, &prompt, 16);
    assert_eq!(tokens.len(), 16);
    assert_eq!(m.prompt_tokens, 24);
    assert_eq!(m.decoded_tokens, 16);
    assert!(m.prefill_secs > 0.0 && m.decode_secs > 0.0);
    // prefill processes 24 tokens in far less than 24 decode steps' time
    assert!(m.prefill_secs < m.decode_secs, "prefill {m:?}");
    assert!(s.pos == 24 + 16);
}

#[test]
fn generation_stops_at_kv_capacity() {
    let mut e = engine("dynamic");
    let cap = e.cfg.t_max;
    let mut s = e.new_session();
    let (tokens, _) = e.generate(&mut s, &[1, 2, 3, 4], cap); // asks for too many
    assert_eq!(tokens.len(), cap - 4);
    assert_eq!(s.remaining_capacity(&e.cfg), 0);
}

#[test]
fn sessions_are_independent() {
    let mut e = engine("dynamic");
    let mut s1 = e.new_session();
    let mut s2 = e.new_session();
    let a1 = e.decode_step(&mut s1, 5);
    let _ = e.decode_step(&mut s2, 400); // different token, separate cache
    let mut s3 = e.new_session();
    let a3 = e.decode_step(&mut s3, 5);
    assert_eq!(a1, a3, "session state leaked");
}

#[test]
fn perf_table_transfers_across_requests() {
    // the table learned on request 1 makes request 2's *first kernel*
    // already balanced — the paper's persistent-runtime property. (The
    // table converges within a couple of kernels, so the step-level
    // timing difference is tiny; the kernel-level difference is not.)
    use dynpar::exec::PhantomWork;
    use dynpar::kernels::cost;
    // compute-bound probe of the trained (GemmI8, VNNI) row — the prefill
    // matmul class — large enough that dispatch overhead is negligible
    let probe = PhantomWork::new(cost::qmatmul_cost(64, 4096, 4096));

    let mut cold_engine = engine("dynamic");
    let cold = cold_engine.rt.run(&probe).wall_secs; // flat table

    let mut warm_engine = engine("dynamic");
    let mut s1 = warm_engine.new_session();
    warm_engine.generate(&mut s1, &[1, 2, 3, 4], 4); // request 1 trains the table
    let warm = warm_engine.rt.run(&probe).wall_secs; // learned table persists
    assert!(warm < cold * 0.9, "no cross-request learning: cold {cold} → warm {warm}");
    // and the learned ratios are visibly hybrid
    let rel = warm_engine
        .rt
        .relative_ratios(dynpar::kernels::KernelClass::GemmI8, dynpar::cpu::Isa::AvxVnni)
        .unwrap();
    assert!(rel[0] > 1.2, "ratios not learned: {rel:?}");
}

#[test]
fn int_gemv_mode_generates_plausible_tokens() {
    let mut ef = engine("dynamic");
    let mut ei = engine("dynamic");
    ei.opts.int_gemv = true;
    let prompt = [3u32, 1, 4, 1, 5];
    let mut sf = ef.new_session();
    let mut si = ei.new_session();
    let (tf, _) = ef.generate(&mut sf, &prompt, 8);
    let (ti, _) = ei.generate(&mut si, &prompt, 8);
    // int path is quantized so tokens may differ eventually, but the
    // first tokens (largest logit margins) should coincide
    assert_eq!(tf[0], ti[0], "f32 {tf:?} vs int {ti:?}");
}

#[test]
fn micro_model_full_pipeline_on_12900k() {
    let cfg = ModelConfig::micro();
    let weights = Arc::new(ModelWeights::random_init(&cfg, 9));
    let exec = SimExecutor::new(
        presets::core_12900k(),
        SimConfig { execute_real: true, ..SimConfig::noiseless() },
    );
    let mut e = Engine::new(
        cfg,
        weights,
        exec,
        scheduler_by_name("dynamic").unwrap(),
        PerfConfig::default(),
    );
    let mut s = e.new_session();
    let (tokens, m) = e.generate(&mut s, &[1, 2, 3], 10);
    assert_eq!(tokens.len(), 10);
    assert!(m.decode_tokens_per_sec() > 0.0);
}
