//! Acceptance tier for the continuous-batching serving layer, driven
//! entirely through the deterministic harness (`dynpar::server::testing`):
//! scripted virtual-time arrivals over simulator leases — no sockets, no
//! wall-clock sleeps, bit-for-bit reproducible.
//!
//! * Continuous batching must beat the old run-to-completion batcher on
//!   mean TTFT by ≥ 25% at equal aggregate throughput (± 5%) under a
//!   Poisson arrival script, with every token stream bit-identical to a
//!   solo `Engine::generate` run.
//! * A stream arriving mid-run must trigger `Coordinator::admit` + fleet
//!   rebuild (epoch bump, leases stay disjoint/covering), in-flight
//!   sessions must migrate bit-identically, a departing stream's cores
//!   must return to the pool, and epoch-stale observations must be
//!   dropped.
//! * A background load degrading half of one stream's cores mid-trace must
//!   be detected from timing alone: the `DriftMonitor` (the same component
//!   `serve_dynamic`'s supervisor runs) fires `rebalance()` from the
//!   serving loop, the epoch bumps, in-flight streams migrate
//!   bit-identically, and aggregate throughput recovers ≥ 10% over the
//!   same trace with the monitor disabled.

use std::sync::Arc;

use dynpar::coordinator::{AllocPolicy, Coordinator, Lease};
use dynpar::cpu::{presets, CoreKind, CpuSpec};
use dynpar::engine::Engine;
use dynpar::model::{ModelConfig, ModelWeights};
use dynpar::perf::PerfConfig;
use dynpar::sched::DynamicScheduler;
use dynpar::server::fleet::{DriftMonitor, EngineFactory};
use dynpar::server::protocol::Request;
use dynpar::server::testing::{run_fleet, run_single, AdmitMode, TraceEvent};
use dynpar::server::{BatcherOpts, LeaseBatcher};
use dynpar::sim::xpu::XpuDispatch;
use dynpar::sim::{SimConfig, SimExecutor};

const WEIGHTS_SEED: u64 = 17;

fn full_machine_engine() -> Engine<SimExecutor> {
    let cfg = ModelConfig::micro();
    let weights = Arc::new(ModelWeights::random_init(&cfg, WEIGHTS_SEED));
    let exec = SimExecutor::new(
        presets::core_12900k(),
        SimConfig { execute_real: true, ..SimConfig::noiseless() },
    );
    Engine::new(cfg, weights, exec, Box::new(DynamicScheduler), PerfConfig::default())
}

fn lease_factory() -> EngineFactory<SimExecutor> {
    let machine = presets::core_12900k();
    let cfg = ModelConfig::micro();
    let weights = Arc::new(ModelWeights::random_init(&cfg, WEIGHTS_SEED));
    Box::new(move |lease: &Lease, _dispatch: XpuDispatch| {
        let exec = lease
            .sim_executor(&machine, SimConfig { execute_real: true, ..SimConfig::noiseless() });
        Engine::new(
            cfg.clone(),
            Arc::clone(&weights),
            exec,
            Box::new(DynamicScheduler),
            PerfConfig::default(),
        )
    })
}

/// One frozen Poisson draw (mean inter-arrival 800 µs, generator seed 93)
/// — scripted so the run is reproducible to the bit.
const ARRIVALS: [f64; 12] = [
    4.279738444e-4,
    5.933389609e-4,
    6.425614994e-4,
    1.863223014e-3,
    3.107279900e-3,
    3.414893644e-3,
    3.627056255e-3,
    5.190387056e-3,
    6.212580151e-3,
    6.253104837e-3,
    6.536602906e-3,
    6.673583587e-3,
];
const PROMPT_LENS: [usize; 12] = [6, 4, 8, 5, 7, 4, 6, 8, 5, 7, 6, 4];
const MAX_NEW: [usize; 12] = [20, 12, 24, 16, 22, 14, 18, 24, 12, 20, 16, 22];

fn poisson_script() -> Vec<TraceEvent> {
    (0..12)
        .map(|i| {
            let prompt: Vec<u32> = (0..PROMPT_LENS[i] as u32).map(|t| t * 7 + i as u32).collect();
            TraceEvent::arrive(
                ARRIVALS[i],
                0,
                Request { id: i as u64, prompt, max_new_tokens: MAX_NEW[i] },
            )
        })
        .collect()
}

fn solo_tokens(id: usize) -> Vec<u32> {
    let mut engine = full_machine_engine();
    let prompt: Vec<u32> = (0..PROMPT_LENS[id] as u32).map(|t| t * 7 + id as u32).collect();
    let mut session = engine.new_session();
    let (tokens, _) = engine.generate(&mut session, &prompt, MAX_NEW[id]);
    tokens
}

/// Acceptance: continuous batching vs the run-to-completion baseline on
/// the same engine, same scripted Poisson arrivals. ≥ 25% better mean
/// TTFT at equal (± 5%) aggregate throughput, identical token streams.
#[test]
fn continuous_batching_beats_run_to_completion_on_ttft() {
    let opts = BatcherOpts { max_batch: 4, prefill_chunk: 4 };
    let cont = run_single(
        LeaseBatcher::new(full_machine_engine(), None, opts),
        AdmitMode::Continuous,
        64,
        poisson_script(),
    );
    let rtc = run_single(
        LeaseBatcher::new(full_machine_engine(), None, opts),
        AdmitMode::RunToCompletion,
        64,
        poisson_script(),
    );

    assert!(cont.all_finished() && rtc.all_finished());
    assert!(cont.rejected.is_empty() && rtc.rejected.is_empty());
    assert_eq!(cont.total_decoded, rtc.total_decoded);
    assert!(cont.total_decoded >= 200, "decoded {}", cont.total_decoded);

    // batching policy never changes the numbers: streams are identical
    // across modes and bit-identical to solo generate() runs
    for id in 0..12u64 {
        let solo = solo_tokens(id as usize);
        assert_eq!(cont.tokens_of(id), &solo[..], "request {id} (continuous)");
        assert_eq!(rtc.tokens_of(id), &solo[..], "request {id} (run-to-completion)");
    }

    // ---- the tentpole claim ----
    let (t_cont, t_rtc) = (cont.mean_ttft(), rtc.mean_ttft());
    assert!(t_cont > 0.0 && t_rtc > 0.0);
    assert!(
        t_cont <= 0.75 * t_rtc,
        "continuous batching must cut mean TTFT by >=25%: cont {:.1}us vs rtc {:.1}us ({:.1}%)",
        t_cont * 1e6,
        t_rtc * 1e6,
        (1.0 - t_cont / t_rtc) * 100.0
    );
    let (x, y) = (cont.throughput(), rtc.throughput());
    assert!(
        (x - y).abs() / y < 0.05,
        "aggregate throughput must stay equal (+-5%): cont {x:.1} vs rtc {y:.1} tok/s"
    );

    // per-round queue depth was sampled and stayed within the bound
    assert!(!cont.queue_depth_samples.is_empty());
    assert!(cont.queue_depth_samples.iter().all(|&d| d <= 64));
}

/// The same scripted run is reproducible to the bit — the harness is a
/// deterministic substrate, not a statistical one.
#[test]
fn harness_runs_are_bit_reproducible() {
    let opts = BatcherOpts { max_batch: 4, prefill_chunk: 4 };
    let a = run_single(
        LeaseBatcher::new(full_machine_engine(), None, opts),
        AdmitMode::Continuous,
        64,
        poisson_script(),
    );
    let b = run_single(
        LeaseBatcher::new(full_machine_engine(), None, opts),
        AdmitMode::Continuous,
        64,
        poisson_script(),
    );
    assert_eq!(a.mean_ttft(), b.mean_ttft());
    assert_eq!(a.makespan, b.makespan);
    for id in 0..12u64 {
        assert_eq!(a.tokens_of(id), b.tokens_of(id));
        assert_eq!(
            a.requests[&id].finished_at, b.requests[&id].finished_at,
            "request {id} finish time"
        );
    }
}

/// Dynamic lease lifecycle end-to-end: a stream arriving mid-run carves
/// out a lease (epoch bump, cores stay disjoint/covering), in-flight
/// sessions migrate bit-identically, the departing stream's cores return
/// to the pool, and epoch-stale observations are dropped.
#[test]
fn mid_run_stream_arrival_and_departure_rebuild_the_fleet() {
    let machine = presets::core_12900k();
    let factory = lease_factory();
    let req = |id: u64, prompt: &[u32], max_new: usize| Request {
        id,
        prompt: prompt.to_vec(),
        max_new_tokens: max_new,
    };
    let trace = vec![
        TraceEvent::Connect { at: 0.0, stream: 10 },
        TraceEvent::arrive(0.0, 10, req(1, &[1, 2, 3, 4], 16)),
        TraceEvent::arrive(1.0e-5, 10, req(2, &[7, 8], 12)),
        // stream 20 shows up while 1 and 2 are decoding...
        TraceEvent::Connect { at: 1.0e-3, stream: 20 },
        TraceEvent::arrive(1.0e-3, 20, req(3, &[5, 6, 9], 14)),
        // ...and leaves again while its own request may still be in flight
        TraceEvent::Disconnect { at: 1.3e-3, stream: 20 },
    ];
    let report = run_fleet(
        Coordinator::new(machine.clone(), AllocPolicy::Balanced),
        &factory,
        BatcherOpts { max_batch: 4, prefill_chunk: 4 },
        64,
        DriftMonitor::disabled(),
        trace,
    );

    // three membership changes → three rebuilds, strictly increasing epochs
    assert_eq!(report.rebuilds, 3);
    assert_eq!(report.epochs_seen.len(), 3);
    assert!(report.epochs_seen.windows(2).all(|w| w[1] > w[0]), "{:?}", report.epochs_seen);

    // every epoch's lease set is disjoint and covers the machine
    for (e, leases) in report.lease_sets.iter().enumerate() {
        let mut seen = vec![false; machine.n_cores()];
        for lease in leases {
            for &c in &lease.cores() {
                assert!(!seen[c], "epoch set {e}: core {c} leased twice");
                seen[c] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "epoch set {e}: leases do not cover the machine");
    }
    // mid-run arrival: both streams got a non-empty half-machine lease
    let two = &report.lease_sets[1];
    assert_eq!(two.len(), 2);
    for lease in two {
        assert_eq!(lease.n_cores(), 8, "balanced halves, got {:?}", lease.cores());
    }
    // departure: the survivor's lease grows back to the whole machine
    let last = report.lease_sets.last().unwrap();
    assert_eq!(last.len(), 1);
    assert_eq!(last[0].stream, 10);
    assert_eq!(last[0].n_cores(), machine.n_cores());

    // all requests completed; streams bit-identical to solo runs even
    // though every one of them migrated across at least one rebuild
    assert!(report.all_finished());
    let oracle = |prompt: &[u32], max_new: usize| {
        let mut engine = full_machine_engine();
        let mut session = engine.new_session();
        engine.generate(&mut session, prompt, max_new).0
    };
    assert_eq!(report.tokens_of(1), &oracle(&[1, 2, 3, 4], 16)[..]);
    assert_eq!(report.tokens_of(2), &oracle(&[7, 8], 12)[..]);
    assert_eq!(report.tokens_of(3), &oracle(&[5, 6, 9], 14)[..]);
    // the mid-run stream was actually served mid-run
    let r3 = &report.requests[&3];
    assert_eq!(r3.arrived_at, 1.0e-3);
    assert!(r3.ttft().unwrap() > 0.0);

    // measurements from the torn-down epoch were replayed after each
    // rebuild: every one dropped, none mis-attributed; live measurements
    // kept feeding the strength table
    assert!(report.stale_observations_dropped >= 2, "{}", report.stale_observations_dropped);
    assert_eq!(report.stale_observations_accepted, 0);
    assert!(report.observations_accepted > 0);
}

// ---- strategy-router scenario ----

/// Live strategy routing end-to-end through the policy API: a scripted
/// chat → burst → chat trace drives the [`dynpar::router::StrategyRouter`]
/// through both Schmitt thresholds (IntraKernel → Disaggregated → back),
/// every switch is a fleet rebuild whose in-flight sessions migrate with
/// bit-identical token streams, and a class-0 request landing inside the
/// class-1 burst is admitted ahead of the queued lower-priority work.
#[test]
fn strategy_router_switches_live_with_bit_identical_streams() {
    use dynpar::coordinator::ExecMode;
    use dynpar::router::{RouterConfig, ServingPolicy};
    use dynpar::server::testing::run_trace;

    let machine = presets::core_12900k();
    let factory = lease_factory();
    let chat = |id: u64| Request {
        id,
        prompt: vec![id as u32 + 1, 3, 9],
        max_new_tokens: 12,
    };
    let burst = |id: u64| Request {
        id,
        prompt: (0..20).map(|k| (id as u32 * 5 + k) % 128).collect(),
        max_new_tokens: 2,
    };
    let mut trace = vec![TraceEvent::Connect { at: 0.0, stream: 0 }];
    // phase A: decode-heavy (prefill share 0.2) — the router holds the
    // blended strategy
    for i in 0..4u64 {
        trace.push(TraceEvent::arrive(1.0e-6 + i as f64 * 1.0e-5, 0, chat(i)));
    }
    // phase B: prompt-heavy class-1 burst (share 0.91) — switch to
    // Disaggregated; one class-0 chat request lands inside the burst
    for i in 0..4u64 {
        trace.push(TraceEvent::arrive_class(2.0e-3 + i as f64 * 1.0e-6, 0, burst(4 + i), 1));
    }
    trace.push(TraceEvent::arrive(2.0e-3 + 5.0e-6, 0, chat(8)));
    // phase C: decode-heavy again — switch back
    for i in 0..4u64 {
        trace.push(TraceEvent::arrive(4.0e-3 + i as f64 * 1.0e-5, 0, chat(9 + i)));
    }
    let policy = ServingPolicy::builder()
        .max_batch(2)
        .prefill_chunk(4)
        .queue_depth(64)
        .drift(f64::INFINITY, 0)
        .slo(0, f64::INFINITY)
        .class("batch", f64::INFINITY, true)
        .router(RouterConfig { window: 4, cooldown_secs: 0.0, ..RouterConfig::default() })
        .build()
        .expect("test policy validates");
    let report = run_trace(
        Coordinator::new(machine, AllocPolicy::Balanced),
        &factory,
        &policy,
        trace,
    );

    // the router crossed both thresholds, exactly once each
    let modes: Vec<ExecMode> = report.strategy_switches.iter().map(|(_, s)| s.mode).collect();
    assert_eq!(
        modes,
        vec![ExecMode::Disaggregated, ExecMode::IntraKernel],
        "switches {:?}",
        report.strategy_switches
    );
    assert!(
        report.strategy_switches.windows(2).all(|w| w[1].0 > w[0].0),
        "switch times not increasing: {:?}",
        report.strategy_switches
    );
    // connect + two strategy switches, each a real rebuild
    assert_eq!(report.rebuilds, 3);

    // class-0 chat landed while both engine slots were chewing the burst:
    // it must jump the two still-queued class-1 requests
    let pos = |id: u64| {
        report
            .admit_order
            .iter()
            .position(|&(i, _)| i == id)
            .unwrap_or_else(|| panic!("request {id} never admitted"))
    };
    assert!(
        pos(8) < pos(6) && pos(8) < pos(7),
        "class-0 request did not jump the class-1 backlog: {:?}",
        report.admit_order
    );
    // nothing was shed: class 0 has no finite TTFT target to protect
    assert!(report.shed.is_empty(), "unexpected sheds: {:?}", report.shed);

    // every stream bit-identical to a solo run even though every in-flight
    // session crossed at least one strategy migration
    assert!(report.all_finished());
    let oracle = |prompt: &[u32], max_new: usize| {
        let mut engine = full_machine_engine();
        let mut session = engine.new_session();
        engine.generate(&mut session, prompt, max_new).0
    };
    for id in 0..13u64 {
        let (prompt, max_new) = if (4..8).contains(&id) {
            ((0..20).map(|k| (id as u32 * 5 + k) % 128).collect::<Vec<u32>>(), 2)
        } else {
            (vec![id as u32 + 1, 3, 9], 12)
        };
        assert_eq!(
            report.tokens_of(id),
            &oracle(&prompt, max_new)[..],
            "request {id} diverged across a strategy switch"
        );
    }
}

// ---- background-drift scenario ----

/// A 12900K with an abundant memory subsystem: every serving kernel of the
/// micro model is compute-bound, so a cycle-stealing background load is
/// visible in the measured per-core rates (the drift signal) *and* costly
/// to throughput — on the stock preset the decode path is bus-bound, where
/// per-core cycle steals neither show in rates nor cost tokens/s.
fn compute_bound_machine() -> CpuSpec {
    let mut spec = presets::core_12900k();
    spec.name = "core_12900k_cb".into();
    for c in spec.cores.iter_mut() {
        c.mem_bw_gbps *= 50.0;
    }
    spec.bus_bw_gbps *= 50.0;
    spec
}

/// Zero kernel-launch overheads so round time tracks core speed (the
/// micro model's kernels are ns-scale; the default 2 µs dispatch overhead
/// would swamp the very signal under test).
fn compute_bound_sim_config() -> SimConfig {
    SimConfig {
        execute_real: true,
        dispatch_overhead_secs: 0.0,
        chunk_claim_overhead_secs: 0.0,
        ..SimConfig::noiseless()
    }
}

fn drift_factory(machine: CpuSpec) -> EngineFactory<SimExecutor> {
    let cfg = ModelConfig::micro();
    let weights = Arc::new(ModelWeights::random_init(&cfg, WEIGHTS_SEED));
    Box::new(move |lease: &Lease, _dispatch: XpuDispatch| {
        let exec = lease.sim_executor(&machine, compute_bound_sim_config());
        Engine::new(
            cfg.clone(),
            Arc::clone(&weights),
            exec,
            Box::new(DynamicScheduler),
            PerfConfig::default(),
        )
    })
}

const DRIFT_AT: f64 = 2.0e-5;
const TAIL_AT: f64 = 2.5e-5;

/// Two streams; a warm-up wave converges the learned state, then a
/// background process steals 50% of half of stream 10's cores (its four
/// P-cores) and a heavy wave lands on both streams.
fn drift_trace(degraded: Vec<usize>) -> Vec<TraceEvent> {
    let req = |id: u64, max_new: usize| Request {
        id,
        prompt: vec![(id as u32) * 3 + 1, 7, 2, 9],
        max_new_tokens: max_new,
    };
    let mut trace = vec![
        TraceEvent::Connect { at: 0.0, stream: 10 },
        TraceEvent::Connect { at: 0.0, stream: 20 },
    ];
    for id in 0..4u64 {
        trace.push(TraceEvent::arrive(1.0e-6, 10, req(id, 8)));
    }
    trace.push(TraceEvent::Degrade { at: DRIFT_AT, cores: degraded, fraction: 0.5 });
    for id in 4..12u64 {
        trace.push(TraceEvent::arrive(TAIL_AT, if id % 2 == 0 { 10 } else { 20 }, req(id, 24)));
    }
    trace
}

/// Aggregate decode throughput over the loaded (post-degrade) period.
fn tail_throughput(report: &dynpar::server::testing::HarnessReport) -> f64 {
    let last = (4..12u64)
        .map(|id| report.requests[&id].finished_at.expect("tail request unfinished"))
        .fold(0.0f64, f64::max);
    8.0 * 24.0 / (last - TAIL_AT)
}

/// Acceptance: the drift monitor closes the observe→rebalance loop from
/// the serving loop itself. Degrading half of stream 10's cores mid-trace
/// skews the learned strengths past the threshold, `rebalance()` fires
/// (epoch bump, degraded cores spread evenly), in-flight token streams
/// migrate bit-identically, and aggregate throughput over the loaded
/// period recovers ≥ 10% vs. the identical trace without the monitor.
#[test]
fn background_drift_triggers_live_rebalance_and_recovers_throughput() {
    let machine = compute_bound_machine();
    // stream 10's P-cores, computed from an identical coordinator replica
    // (the harness admits 10 then 20 at t = 0)
    let mut replica = Coordinator::new(machine.clone(), AllocPolicy::Balanced);
    replica.admit(10);
    replica.admit(20);
    let degraded: Vec<usize> = replica
        .lease(10)
        .unwrap()
        .cores()
        .into_iter()
        .filter(|&g| machine.cores[g].kind == CoreKind::Performance)
        .collect();
    assert_eq!(degraded.len(), 4);

    let opts = BatcherOpts { max_batch: 4, prefill_chunk: 4 };
    let monitored = run_fleet(
        Coordinator::new(machine.clone(), AllocPolicy::Balanced),
        &drift_factory(machine.clone()),
        opts,
        64,
        DriftMonitor::new(1.25, 8),
        drift_trace(degraded.clone()),
    );
    let unmonitored = run_fleet(
        Coordinator::new(machine.clone(), AllocPolicy::Balanced),
        &drift_factory(machine.clone()),
        opts,
        64,
        DriftMonitor::disabled(),
        drift_trace(degraded.clone()),
    );

    // the monitor fired exactly once, from the serving loop (the harness
    // runs the same DriftMonitor serve_dynamic's supervisor consults),
    // with the learned skew past the threshold; the healthy phase and the
    // freshly rebalanced partition never re-fire
    assert_eq!(monitored.drift_rebalances, 1, "skews {:?}", monitored.skew_at_trigger);
    assert!(monitored.skew_at_trigger[0] > 1.25, "skew {:?}", monitored.skew_at_trigger);
    assert_eq!(monitored.rebuilds, 2);
    assert_eq!(monitored.epochs_seen, vec![2, 3], "rebalance must bump the epoch");
    assert_eq!(unmonitored.drift_rebalances, 0);
    assert_eq!(unmonitored.epochs_seen, vec![2]);

    // the rebalance spread the degraded cores evenly across both leases
    let last = monitored.lease_sets.last().unwrap();
    assert_eq!(last.len(), 2);
    for lease in last {
        let n = lease.cores().iter().filter(|c| degraded.contains(c)).count();
        assert_eq!(n, 2, "degraded cores not spread: {:?}", lease.cores());
    }

    // every request of both runs finished, with bit-identical streams:
    // the live rebalance migrated in-flight sessions without changing a
    // single token — and both match a solo oracle run
    assert!(monitored.all_finished() && unmonitored.all_finished());
    assert_eq!(monitored.total_decoded, unmonitored.total_decoded);
    assert_eq!(monitored.total_decoded, 4 * 8 + 8 * 24);
    for id in 0..12u64 {
        assert!(!monitored.tokens_of(id).is_empty(), "request {id} produced nothing");
        assert_eq!(monitored.tokens_of(id), unmonitored.tokens_of(id), "request {id}");
    }
    for id in [4u64, 11] {
        let cfg = ModelConfig::micro();
        let weights = Arc::new(ModelWeights::random_init(&cfg, WEIGHTS_SEED));
        let exec = SimExecutor::new(machine.clone(), compute_bound_sim_config());
        let mut engine = Engine::new(
            cfg,
            weights,
            exec,
            Box::new(DynamicScheduler),
            PerfConfig::default(),
        );
        let mut session = engine.new_session();
        let prompt = vec![(id as u32) * 3 + 1, 7, 2, 9];
        let (expect, _) = engine.generate(&mut session, &prompt, 24);
        assert_eq!(monitored.tokens_of(id), &expect[..], "request {id} vs oracle");
    }

    // ---- the drift-recovery claim ----
    let (with, without) = (tail_throughput(&monitored), tail_throughput(&unmonitored));
    assert!(
        with >= 1.10 * without,
        "rebalance recovered {:.1}% (monitored {with:.0} vs unmonitored {without:.0} tok/s)",
        (with / without - 1.0) * 100.0
    );
}
