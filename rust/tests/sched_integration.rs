//! Integration: the full dynamic-parallel loop (scheduler + perf table +
//! simulator) under the scenarios the paper claims to handle — cold start,
//! convergence, background-load changes, scheduler comparisons.

use dynpar::cpu::{presets, Isa};
use dynpar::exec::{ParallelRuntime, PhantomWork};
use dynpar::kernels::{cost, KernelClass};
use dynpar::perf::PerfConfig;
use dynpar::sched::scheduler_by_name;
use dynpar::sim::{BackgroundLoad, NoiseConfig, SimConfig, SimExecutor};

fn runtime(preset: &str, sched: &str, sim_cfg: SimConfig) -> ParallelRuntime<SimExecutor> {
    let spec = presets::preset_by_name(preset).unwrap();
    ParallelRuntime::new(
        SimExecutor::new(spec, sim_cfg),
        scheduler_by_name(sched).unwrap(),
        PerfConfig::default(),
    )
}

#[test]
fn cold_start_converges_within_a_few_kernels() {
    let mut rt = runtime("core_12900k", "dynamic", SimConfig::noiseless());
    let work = PhantomWork::new(cost::gemm_i8_cost(1024, 4096, 4096));
    let first = rt.run(&work).wall_secs;
    let mut last = first;
    for _ in 0..6 {
        last = rt.run(&work).wall_secs;
    }
    // paper: "quickly adapt … during program startup"
    assert!(first / last > 1.6, "first {first} last {last}");
    let final_imbalance = rt.run(&work).imbalance();
    assert!(final_imbalance < 1.03, "imbalance {final_imbalance}");
}

#[test]
fn adapts_to_sudden_background_load() {
    // paper §2.2: "maximize CPU performance … when there are sudden
    // changes in the system background"
    let noise = NoiseConfig {
        sigma: 0.0,
        background: vec![BackgroundLoad { core: 0, start: 0.08, end: 1e9, fraction: 0.5 }],
        ..NoiseConfig::disabled()
    };
    let mut rt = runtime("core_12900k", "dynamic", SimConfig { noise, ..SimConfig::noiseless() });
    let work = PhantomWork::new(cost::gemm_i8_cost(1024, 4096, 4096));
    // converge while clean
    let mut clean = f64::INFINITY;
    while rt.exec.sim.now < 0.08 {
        clean = clean.min(rt.run(&work).wall_secs);
    }
    // hit the perturbation, then re-converge
    let mut post = Vec::new();
    for _ in 0..25 {
        post.push(rt.run(&work).wall_secs);
    }
    let spike = post.iter().cloned().fold(0.0, f64::max);
    let settled = post[post.len() - 3..].iter().sum::<f64>() / 3.0;
    // losing half of one P-core costs ~4.5% of total throughput;
    // after re-convergence we must be close to that ideal, not the spike
    let ideal_loss = 1.0 + 0.5 * 2.65 / 29.2; // half a P-core of Σ ratios
    assert!(spike > settled * 1.05, "no visible spike? {post:?}");
    assert!(
        settled < clean * ideal_loss * 1.03,
        "did not re-balance: settled {settled} clean {clean}"
    );
    // the learned ratio of core 0 dropped to ~half of its P-core peers
    let rel = rt.relative_ratios(KernelClass::GemmI8, Isa::AvxVnni).unwrap();
    assert!(
        (rel[0] / rel[1] - 0.5).abs() < 0.05,
        "core0/core1 ratio {:?}",
        rel[0] / rel[1]
    );
}

#[test]
fn dynamic_wins_on_both_paper_cpus_for_both_regimes() {
    for preset in ["core_12900k", "ultra_125h"] {
        for (label, c) in [
            ("gemm", cost::gemm_i8_cost(1024, 4096, 4096)),
            ("gemv", cost::gemv_q4_cost(4096, 4096)),
        ] {
            let work = PhantomWork::new(c);
            let mut stat = runtime(preset, "static", SimConfig::noiseless());
            let mut dynm = runtime(preset, "dynamic", SimConfig::noiseless());
            let mut t_static = 0.0;
            let mut t_dyn = 0.0;
            for _ in 0..12 {
                t_static = stat.run(&work).wall_secs;
                t_dyn = dynm.run(&work).wall_secs;
            }
            assert!(
                t_dyn < t_static,
                "{preset}/{label}: dynamic {t_dyn} not faster than static {t_static}"
            );
        }
    }
}

#[test]
fn dynamic_matches_static_on_homogeneous_cpu() {
    // the control: no imbalance → no benefit, but also no regression
    let work = PhantomWork::new(cost::gemm_i8_cost(1024, 4096, 4096));
    let mut stat = runtime("homogeneous_16", "static", SimConfig::noiseless());
    let mut dynm = runtime("homogeneous_16", "dynamic", SimConfig::noiseless());
    let mut t_static = 0.0;
    let mut t_dyn = 0.0;
    for _ in 0..8 {
        t_static = stat.run(&work).wall_secs;
        t_dyn = dynm.run(&work).wall_secs;
    }
    assert!((t_dyn / t_static - 1.0).abs() < 0.01, "dyn {t_dyn} vs static {t_static}");
}

#[test]
fn dynamic_beats_workstealing_on_small_kernels() {
    // the paper's argument against parallel_for-style stealing: per-chunk
    // claim overhead hurts short (decode GEMV) kernels
    let work = PhantomWork::new(cost::gemv_q4_cost(4096, 4096));
    let mut ws = runtime("ultra_125h", "workstealing", SimConfig::default());
    let mut dy = runtime("ultra_125h", "dynamic", SimConfig::default());
    let mut t_ws = 0.0;
    let mut t_dy = 0.0;
    for _ in 0..20 {
        t_ws = ws.run(&work).wall_secs;
        t_dy = dy.run(&work).wall_secs;
    }
    assert!(t_dy <= t_ws * 1.05, "dynamic {t_dy} vs workstealing {t_ws}");
}

#[test]
fn per_isa_tables_learn_independently() {
    let mut rt = runtime("ultra_125h", "dynamic", SimConfig::noiseless());
    let gemm = PhantomWork::new(cost::gemm_i8_cost(512, 2048, 2048)); // VNNI
    let attn = PhantomWork::new(cost::attention_decode_cost(32, 512, 128)); // AVX2
    for _ in 0..10 {
        rt.run(&gemm);
        rt.run(&attn);
    }
    let vnni = rt.relative_ratios(KernelClass::GemmI8, Isa::AvxVnni).unwrap();
    let avx2 = rt.relative_ratios(KernelClass::Attention, Isa::Avx2).unwrap();
    // both learned hybrid ratios, but different ones (different ISA mix)
    assert!(vnni[0] > 1.5 && avx2[0] > 1.5, "vnni {vnni:?} avx2 {avx2:?}");
    assert!((vnni[0] - avx2[0]).abs() > 0.1, "vnni {} avx2 {}", vnni[0], avx2[0]);
}

#[test]
fn noisy_simulation_stays_stable() {
    // OU noise on: latencies jitter but never diverge, ratios stay sane
    let mut rt = runtime("core_12900k", "dynamic", SimConfig::default());
    let work = PhantomWork::new(cost::gemm_i8_cost(1024, 4096, 4096));
    let mut walls = Vec::new();
    for _ in 0..40 {
        walls.push(rt.run(&work).wall_secs);
    }
    let best = walls.iter().cloned().fold(f64::INFINITY, f64::min);
    let worst_late = walls[10..].iter().cloned().fold(0.0, f64::max);
    assert!(worst_late < best * 1.25, "diverged: best {best}, late worst {worst_late}");
    let rel = rt.relative_ratios(KernelClass::GemmI8, Isa::AvxVnni).unwrap();
    assert!((2.0..3.5).contains(&rel[0]), "ratio {rel:?}");
}

#[test]
fn host_pool_runs_the_full_loop_end_to_end() {
    // real threads (1 host core): correctness of the loop, not timing
    use dynpar::exec::{Executor, FnWork};
    use dynpar::pool::HostPool;
    use std::sync::atomic::{AtomicUsize, Ordering};

    let pool = HostPool::new(4);
    let mut rt =
        ParallelRuntime::new(pool, scheduler_by_name("dynamic").unwrap(), PerfConfig::default());
    assert_eq!(rt.exec.n_workers(), 4);
    let counter = AtomicUsize::new(0);
    for _ in 0..10 {
        let work = FnWork::new(cost::gemv_q4_cost(256, 1024), 1, |_w, r| {
            counter.fetch_add(r.len(), Ordering::Relaxed);
        });
        rt.run(&work);
    }
    assert_eq!(counter.load(Ordering::Relaxed), 10 * 1024);
    assert!(rt.table.update_count() > 0);
}
