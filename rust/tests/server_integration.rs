//! Integration: the TCP serving front-end under realistic client traffic,
//! both through the classic single all-core engine and through a fleet of
//! coordinator-leased engines on disjoint core subsets.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use dynpar::coordinator::{AllocPolicy, Coordinator, ExecMode, Lease};
use dynpar::cpu::presets;
use dynpar::engine::Engine;
use dynpar::model::{ModelConfig, ModelWeights};
use dynpar::perf::PerfConfig;
use dynpar::sched::DynamicScheduler;
use dynpar::server::{serve, serve_dynamic, serve_multi, ServerHandle, ServerOpts};
use dynpar::sim::xpu::XpuDispatch;
use dynpar::sim::{SimConfig, SimExecutor};
use dynpar::util::json::Json;

fn start_server(max_batch: usize) -> ServerHandle {
    let cfg = ModelConfig::micro();
    let weights = Arc::new(ModelWeights::random_init(&cfg, 5));
    let exec = SimExecutor::new(
        presets::ultra_125h(),
        SimConfig { execute_real: true, ..SimConfig::noiseless() },
    );
    let engine =
        Engine::new(cfg, weights, exec, Box::new(DynamicScheduler), PerfConfig::default());
    serve("127.0.0.1:0", engine, ServerOpts { max_batch, ..Default::default() }).unwrap()
}

fn roundtrip(addr: std::net::SocketAddr, line: &str) -> Vec<Json> {
    let mut stream = TcpStream::connect(addr).unwrap();
    writeln!(stream, "{line}").unwrap();
    let reader = BufReader::new(stream);
    let mut out = Vec::new();
    for l in reader.lines() {
        let Ok(l) = l else { break };
        let v = Json::parse(&l).unwrap();
        let fin =
            v.get("done").is_some() || v.get("error").is_some() || v.get("metrics").is_some();
        out.push(v);
        if fin {
            break;
        }
    }
    out
}

/// Start a multi-engine server: one engine per coordinator lease, each
/// executor restricted to its lease's disjoint core subset of `machine`.
fn start_lease_server(n_leases: usize, max_batch: usize) -> ServerHandle {
    let machine = presets::core_12900k();
    let cfg = ModelConfig::micro();
    let weights = Arc::new(ModelWeights::random_init(&cfg, 5));
    let mut coord = Coordinator::new(machine.clone(), AllocPolicy::Balanced);
    for s in 0..n_leases as u64 {
        coord.admit(s);
    }
    let engines: Vec<Engine<SimExecutor>> = coord
        .leases()
        .map(|lease| {
            let exec = lease.sim_executor(
                &machine,
                SimConfig { execute_real: true, ..SimConfig::noiseless() },
            );
            Engine::new(
                cfg.clone(),
                Arc::clone(&weights),
                exec,
                Box::new(DynamicScheduler),
                PerfConfig::default(),
            )
        })
        .collect();
    serve_multi("127.0.0.1:0", engines, ServerOpts { max_batch, ..Default::default() }).unwrap()
}

/// Start a dynamic-membership server: the lease set follows the live
/// connections (first generate request admits, disconnect finishes).
fn start_dynamic_server() -> ServerHandle {
    let machine = presets::core_12900k();
    let cfg = ModelConfig::micro();
    let weights = Arc::new(ModelWeights::random_init(&cfg, 5));
    let factory = {
        let machine = machine.clone();
        move |lease: &Lease, _dispatch: XpuDispatch| {
            let exec = lease.sim_executor(
                &machine,
                SimConfig { execute_real: true, ..SimConfig::noiseless() },
            );
            Engine::new(
                cfg.clone(),
                Arc::clone(&weights),
                exec,
                Box::new(DynamicScheduler),
                PerfConfig::default(),
            )
        }
    };
    let coord = Coordinator::new(machine, AllocPolicy::Balanced);
    serve_dynamic("127.0.0.1:0", coord, factory, ServerOpts::default()).unwrap()
}

#[test]
fn concurrent_connections_stream_through_separate_leases() {
    // two leases, batch 1: simultaneous requests can only both progress if
    // each lease's engine thread serves one of them
    let handle = start_lease_server(2, 1);
    let addr = handle.addr;
    let joins: Vec<_> = (0..6)
        .map(|i| {
            std::thread::spawn(move || {
                roundtrip(
                    addr,
                    &format!(r#"{{"id": {i}, "prompt": [{}, 3], "max_new_tokens": 4}}"#, i + 1),
                )
            })
        })
        .collect();
    for (i, j) in joins.into_iter().enumerate() {
        let msgs = j.join().unwrap();
        let tokens = msgs.iter().filter(|m| m.get("token").is_some()).count();
        assert_eq!(tokens, 4, "client {i}: {msgs:?}");
        let done = msgs.last().unwrap();
        assert_eq!(done.get("id").unwrap().as_i64(), Some(i as i64));
    }
    let metrics = roundtrip(addr, r#"{"cmd":"metrics"}"#);
    let m = metrics[0].get("metrics").unwrap();
    assert_eq!(m.get("requests").unwrap().as_i64(), Some(6));
    assert_eq!(m.get("tokens").unwrap().as_i64(), Some(24));
    assert_eq!(m.get("engines").unwrap().as_i64(), Some(2));
    handle.shutdown();
}

#[test]
fn lease_fleet_and_single_engine_agree_on_tokens() {
    // same weights, same prompt → identical tokens whether the request is
    // served by an 8-core lease engine or the 16-core single engine
    let fleet = start_lease_server(2, 2);
    let single = start_server(2);
    let get = |addr| {
        roundtrip(addr, r#"{"id": 1, "prompt": [6, 2, 9], "max_new_tokens": 6}"#)
            .iter()
            .filter_map(|m| m.get("token").and_then(Json::as_i64))
            .collect::<Vec<_>>()
    };
    let a = get(fleet.addr);
    assert_eq!(a.len(), 6);
    assert_eq!(a, get(single.addr));
    fleet.shutdown();
    single.shutdown();
}

#[test]
fn ten_concurrent_clients_all_served() {
    let handle = start_server(4);
    let addr = handle.addr;
    let joins: Vec<_> = (0..10)
        .map(|i| {
            std::thread::spawn(move || {
                roundtrip(
                    addr,
                    &format!(r#"{{"id": {i}, "prompt": [{}, 7], "max_new_tokens": 5}}"#, i + 1),
                )
            })
        })
        .collect();
    for (i, j) in joins.into_iter().enumerate() {
        let msgs = j.join().unwrap();
        let tokens = msgs.iter().filter(|m| m.get("token").is_some()).count();
        assert_eq!(tokens, 5, "client {i}: {msgs:?}");
        let done = msgs.last().unwrap();
        assert_eq!(done.get("id").unwrap().as_i64(), Some(i as i64));
        assert!(done.get("tokens_per_sec").unwrap().as_f64().unwrap() > 0.0);
    }
    let metrics = roundtrip(addr, r#"{"cmd":"metrics"}"#);
    let m = metrics[0].get("metrics").unwrap();
    assert_eq!(m.get("requests").unwrap().as_i64(), Some(10));
    assert_eq!(m.get("tokens").unwrap().as_i64(), Some(50));
    handle.shutdown();
}

#[test]
fn same_prompt_same_tokens_regardless_of_batching() {
    let h1 = start_server(1); // no batching
    let h4 = start_server(4); // batched
    let get = |addr| {
        roundtrip(addr, r#"{"id": 1, "prompt": [9, 8, 7], "max_new_tokens": 6}"#)
            .iter()
            .filter_map(|m| m.get("token").and_then(Json::as_i64))
            .collect::<Vec<_>>()
    };
    assert_eq!(get(h1.addr), get(h4.addr));
    h1.shutdown();
    h4.shutdown();
}

#[test]
fn sequential_requests_on_one_connection() {
    let handle = start_server(2);
    let mut stream = TcpStream::connect(handle.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    for req in 0..3 {
        writeln!(stream, r#"{{"id": {req}, "prompt": [1, 2], "max_new_tokens": 2}}"#).unwrap();
        let mut got_done = false;
        while !got_done {
            let mut line = String::new();
            if reader.read_line(&mut line).unwrap() == 0 {
                panic!("connection closed early");
            }
            if line.trim().is_empty() {
                continue;
            }
            let v = Json::parse(line.trim()).unwrap();
            got_done = v.get("done").is_some();
        }
    }
    handle.shutdown();
}

#[test]
fn malformed_lines_do_not_kill_the_connection() {
    let handle = start_server(2);
    let mut stream = TcpStream::connect(handle.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    writeln!(stream, "this is not json").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(Json::parse(line.trim()).unwrap().get("error").is_some());
    // connection still works
    writeln!(stream, r#"{{"id": 5, "prompt": [3], "max_new_tokens": 1}}"#).unwrap();
    let mut saw_done = false;
    for _ in 0..10 {
        let mut l = String::new();
        if reader.read_line(&mut l).unwrap() == 0 {
            break;
        }
        if l.contains("\"done\"") {
            saw_done = true;
            break;
        }
    }
    assert!(saw_done);
    handle.shutdown();
}

/// Start a dynamic server whose leases run phase-disaggregated: each
/// lease becomes a prefill batcher on compute-strong cores plus a decode
/// batcher on the bandwidth-rich rest, linked by the in-process handoff.
fn start_disaggregated_server() -> ServerHandle {
    let machine = presets::core_12900k();
    let cfg = ModelConfig::micro();
    let weights = Arc::new(ModelWeights::random_init(&cfg, 5));
    let factory = {
        let machine = machine.clone();
        move |lease: &Lease, _dispatch: XpuDispatch| {
            let exec = lease.sim_executor(
                &machine,
                SimConfig { execute_real: true, ..SimConfig::noiseless() },
            );
            Engine::new(
                cfg.clone(),
                Arc::clone(&weights),
                exec,
                Box::new(DynamicScheduler),
                PerfConfig::default(),
            )
        }
    };
    let mut coord = Coordinator::new(machine, AllocPolicy::Balanced);
    coord.set_exec_mode(ExecMode::Disaggregated);
    serve_dynamic("127.0.0.1:0", coord, factory, ServerOpts::default()).unwrap()
}

#[test]
fn disaggregated_server_hands_off_and_matches_static_tokens() {
    // the prefill batcher parks the finished prompt, the decode batcher
    // adopts the session through the handoff buffer and streams it — the
    // tokens must match the classic single-engine server bit for bit
    let disagg = start_disaggregated_server();
    let single = start_server(2);
    let get = |addr| {
        roundtrip(addr, r#"{"id": 1, "prompt": [6, 2, 9], "max_new_tokens": 6}"#)
            .iter()
            .filter_map(|m| m.get("token").and_then(Json::as_i64))
            .collect::<Vec<_>>()
    };
    let d = get(disagg.addr);
    assert_eq!(d.len(), 6);
    assert_eq!(d, get(single.addr));
    // the request crossed the prefill→decode seam exactly once
    let metrics = roundtrip(disagg.addr, r#"{"cmd":"metrics"}"#);
    let m = metrics[0].get("metrics").unwrap();
    assert!(m.get("handoffs").unwrap().as_i64().unwrap() >= 1, "{m:?}");
    assert_eq!(m.get("requests").unwrap().as_i64(), Some(1));
    disagg.shutdown();
    single.shutdown();
}

#[test]
fn dynamic_server_serves_and_matches_static_tokens() {
    // a request through the dynamic-membership server produces the same
    // tokens as the classic single-engine server (same weights seed 5)
    let dynamic = start_dynamic_server();
    let single = start_server(2);
    let get = |addr| {
        roundtrip(addr, r#"{"id": 1, "prompt": [6, 2, 9], "max_new_tokens": 6}"#)
            .iter()
            .filter_map(|m| m.get("token").and_then(Json::as_i64))
            .collect::<Vec<_>>()
    };
    let d = get(dynamic.addr);
    assert_eq!(d.len(), 6);
    assert_eq!(d, get(single.addr));
    dynamic.shutdown();
    single.shutdown();
}

#[test]
fn dynamic_server_grows_and_shrinks_with_connections() {
    let handle = start_dynamic_server();
    let addr = handle.addr;
    // two concurrent clients: each connection becomes a coordinator stream
    // with its own lease-restricted engine
    let joins: Vec<_> = (0..2)
        .map(|i| {
            std::thread::spawn(move || {
                roundtrip(
                    addr,
                    &format!(r#"{{"id": {i}, "prompt": [{}, 4], "max_new_tokens": 5}}"#, i + 1),
                )
            })
        })
        .collect();
    for (i, j) in joins.into_iter().enumerate() {
        let msgs = j.join().unwrap();
        assert_eq!(
            msgs.iter().filter(|m| m.get("token").is_some()).count(),
            5,
            "client {i}: {msgs:?}"
        );
        assert!(msgs.iter().any(|m| m.get("done").is_some()));
    }
    // after both clients disconnect the supervisor finishes their streams:
    // 2 admits + 2 finishes = epoch 4, and the fleet shrinks to zero
    // engines. Poll the metrics (a metrics-only probe never becomes a
    // stream) until the rebuild has happened.
    let mut settled = false;
    for _ in 0..300 {
        let metrics = roundtrip(addr, r#"{"cmd":"metrics"}"#);
        let m = metrics[0].get("metrics").unwrap();
        if m.get("epoch").unwrap().as_i64() == Some(4)
            && m.get("engines").unwrap().as_i64() == Some(0)
        {
            assert_eq!(m.get("requests").unwrap().as_i64(), Some(2));
            assert!(m.get("rebuilds").unwrap().as_i64().unwrap() >= 2);
            settled = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert!(settled, "fleet did not shrink after the streams departed");
    handle.shutdown();
}

#[test]
fn shutdown_is_clean_and_idempotent_socketwise() {
    let handle = start_server(2);
    let addr = handle.addr;
    let _ = roundtrip(addr, r#"{"id": 1, "prompt": [2], "max_new_tokens": 1}"#);
    handle.shutdown();
    // connecting after shutdown fails eventually (accept loop gone)
    std::thread::sleep(std::time::Duration::from_millis(100));
    let res = TcpStream::connect(addr);
    // the listener socket is closed; either refused or reset on use
    if let Ok(mut s) = res {
        let _ = writeln!(s, r#"{{"cmd":"metrics"}}"#);
        let mut line = String::new();
        let n = BufReader::new(s).read_line(&mut line).unwrap_or(0);
        assert_eq!(n, 0, "server still answering after shutdown");
    }
}
