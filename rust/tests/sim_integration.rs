//! Integration: the simulated-figure bands — every quantitative claim of
//! the paper's evaluation, asserted end to end through the bench harness.

use dynpar::bench_harness::{fig2, fig3, fig4};

#[test]
fn fig2_gemm_speedups_land_in_paper_bands() {
    // paper: +65% (Ultra-125H), +85% (Core-12900K) for INT8 GEMM
    let res = fig2::run_gemm(
        &["ultra_125h", "core_12900k"],
        &["static", "dynamic"],
        1024,
        4096,
        4096,
        12,
        8,
        false,
    );
    let s125 = fig2::speedup_vs_static(&res, "ultra_125h", "dynamic").unwrap();
    let s129 = fig2::speedup_vs_static(&res, "core_12900k", "dynamic").unwrap();
    assert!((1.55..1.80).contains(&s125), "125H {s125} (paper 1.65)");
    assert!((1.70..1.95).contains(&s129), "12900K {s129} (paper 1.85)");
    // ordering: the 12900K benefits more (its E-core pool is larger)
    assert!(s129 > s125);
}

#[test]
fn fig2_gemv_bandwidth_claims_hold() {
    let res = fig2::run_gemv(
        &["ultra_125h", "core_12900k"],
        &["static", "dynamic"],
        4096,
        4096,
        15,
        8,
        false,
    );
    for cpu in ["ultra_125h", "core_12900k"] {
        let d = res.iter().find(|r| r.cpu == cpu && r.scheduler == "dynamic").unwrap();
        // paper: the dynamic method reaches >90% of the MLC reference
        assert!(d.bandwidth_utilization() > 0.90, "{cpu}: {}", d.bandwidth_utilization());
    }
    // paper: +19% bandwidth on the 125H
    let sp = fig2::speedup_vs_static(&res, "ultra_125h", "dynamic").unwrap();
    assert!((1.08..1.45).contains(&sp), "125H gemv gain {sp} (paper 1.19)");
}

#[test]
fn fig3_e2e_bands_hold_at_paper_scale() {
    // full paper workload: prompt 1024 (this is the slow test of the suite)
    let res = fig3::run(&["ultra_125h", "core_12900k"], 1024, 8, false);
    for cpu in ["ultra_125h", "core_12900k"] {
        let lc = fig3::find(&res, cpu, "llama.cpp").unwrap();
        let ns = fig3::find(&res, cpu, "ns_openmp").unwrap();
        let dy = fig3::find(&res, cpu, "ns_dynamic").unwrap();
        // prefill gain vs NS-OpenMP: paper 20–30% (we accept 15–45%)
        let pg = ns.metrics.prefill_secs / dy.metrics.prefill_secs;
        assert!((1.15..1.45).contains(&pg), "{cpu} prefill gain {pg}");
        // decode gain: paper 9–22% (we accept 2–30%)
        let dg = ns.metrics.decode_secs / dy.metrics.decode_secs;
        assert!((1.02..1.30).contains(&dg), "{cpu} decode gain {dg}");
        // llama.cpp is slowest on both phases
        assert!(lc.metrics.prefill_secs > ns.metrics.prefill_secs);
        assert!(lc.metrics.decode_secs >= ns.metrics.decode_secs);
        // headline: several-fold faster than llama.cpp on prefill
        let headline = lc.metrics.prefill_secs / dy.metrics.prefill_secs;
        assert!(headline > 2.3, "{cpu} headline ×{headline}");
        // decode ≈ 16 tokens/s scale; >90% of MLC bandwidth
        assert!((10.0..25.0).contains(&dy.decode_tps()), "{cpu} tps {}", dy.decode_tps());
        assert!(
            dy.decode_bandwidth_gbps / dy.mlc_gbps > 0.9,
            "{cpu} util {}",
            dy.decode_bandwidth_gbps / dy.mlc_gbps
        );
    }
}

#[test]
fn fig4_trace_has_both_transitions() {
    let trace = fig4::run(&fig4::Fig4Params {
        prompt_len: 512,
        n_decode: 32,
        noisy: true, // the paper's trace is visibly noisy
        ..Default::default()
    });
    let prefill: Vec<f64> =
        trace.samples.iter().filter(|s| s.phase == "prefill").map(|s| s.ratio).collect();
    // transition 1: 5 → 3..3.5 stabilization
    assert!(prefill[0] > 3.3, "starts adapting from 5: {}", prefill[0]);
    let tail = &prefill[prefill.len() / 2..];
    let tail_mean: f64 = tail.iter().sum::<f64>() / tail.len() as f64;
    assert!((2.7..3.6).contains(&tail_mean), "prefill tail {tail_mean}");
    // transition 2: decode settles at a different level
    let decode_mean = trace.phase_mean("decode").unwrap();
    assert!((decode_mean - tail_mean).abs() > 0.2, "no phase shift: {decode_mean} vs {tail_mean}");
}

#[test]
fn mlc_reference_is_consistent_with_gemv_ceiling() {
    use dynpar::cpu::presets;
    use dynpar::sim::{HybridSim, SimConfig};
    for preset in ["ultra_125h", "core_12900k"] {
        let spec = presets::preset_by_name(preset).unwrap();
        let mlc = HybridSim::new(spec.clone(), SimConfig::noiseless()).mlc_bandwidth();
        // sanity: the reference is positive and ≤ the bus
        assert!(mlc > 0.0 && mlc <= spec.bus_bw_gbps + 1e-9);
        // and no scheduler result may exceed it
        let res = fig2::run_gemv(&[preset], &["dynamic"], 4096, 4096, 15, 5, false);
        assert!(res[0].bandwidth_gbps <= mlc * 1.001, "{preset}");
    }
}
