//! Steady-state allocation-freedom of the host inference path.
//!
//! A counting `#[global_allocator]` wraps the system allocator. After one
//! warm-up round has sized the engine's scratch arena, the perf table and
//! the pool's result buffers, further token rounds — decode steps and
//! same-shape prefills through a recycled [`SessionPool`] slot — must hit
//! the allocator exactly zero times. This is the regression fence for the
//! arena refactor: any `vec![..]`/`to_vec()` that sneaks back into the
//! decode/prefill/gemv/qmatmul/attention hot path trips it immediately.
//!
//! Everything runs inside a single `#[test]` so no concurrent test thread
//! can allocate while the steady-state window is being measured.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use dynpar::engine::Engine;
use dynpar::model::{argmax, ModelConfig, ModelWeights, SessionPool};
use dynpar::perf::PerfConfig;
use dynpar::pool::HostPool;
use dynpar::sched::DynamicScheduler;

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOC_CALLS.load(Ordering::SeqCst)
}

#[test]
fn steady_state_token_rounds_are_allocation_free() {
    let cfg = ModelConfig::micro();
    let weights = Arc::new(ModelWeights::random_init(&cfg, 17));
    let pool = HostPool::new(2);
    let mut engine =
        Engine::new(cfg, weights, pool, Box::new(DynamicScheduler), PerfConfig::default());

    // ---- decode: warm up, then count ----
    let prompt = [3u32, 9, 1, 7, 5, 2];
    let mut session = engine.new_session();
    let mut next = argmax(engine.prefill_in(&mut session, &prompt));
    for _ in 0..4 {
        next = argmax(engine.decode_step_in(&mut session, next));
    }
    let before = allocs();
    for _ in 0..8 {
        next = argmax(engine.decode_step_in(&mut session, next));
    }
    let decode_allocs = allocs() - before;
    assert_eq!(
        decode_allocs, 0,
        "steady-state decode performed {decode_allocs} heap allocations"
    );

    // ---- prefill through a recycled KV slot: warm cycle, counted cycle ----
    // (regression fence for the once-per-closure `vec![0.0; k]` the qmatmul
    // path used to allocate on every prefill)
    let mut slots = SessionPool::new(&engine.cfg, 1);
    let mut s = slots.acquire().unwrap();
    let warm = argmax(engine.prefill_in(&mut s, &prompt));
    slots.release(s);
    let before = allocs();
    let mut s = slots.acquire().unwrap();
    let counted = argmax(engine.prefill_in(&mut s, &prompt));
    slots.release(s);
    let prefill_allocs = allocs() - before;
    assert_eq!(
        prefill_allocs, 0,
        "steady-state prefill performed {prefill_allocs} heap allocations"
    );
    // the recycled slot replays the identical prompt → identical next token
    assert_eq!(warm, counted);

    // the engine still works after being measured (sanity, and keeps the
    // decode chain's tokens observable)
    assert!((next as usize) < engine.cfg.vocab);
}
