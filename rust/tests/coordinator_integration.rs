//! Integration: the coordinator's multi-stream serving story on the
//! deterministic simulator — two concurrent phantom-decode streams on
//! disjoint, topology-aware core leases beat the same two streams
//! serialized through one all-core engine, and a mid-run background-load
//! shift is detected from measured per-core times and answered by a
//! rebalance that spreads the degraded cores across streams.

use dynpar::coordinator::{AllocPolicy, Coordinator, Lease};
use dynpar::cpu::{presets, CoreKind, CpuSpec};
use dynpar::engine::phantom::{decode_invocations, PhantomSystem};
use dynpar::exec::{ParallelRuntime, PhantomWork};
use dynpar::kernels::{cost, KernelClass};
use dynpar::model::ModelConfig;
use dynpar::perf::PerfConfig;
use dynpar::sched::DynamicScheduler;
use dynpar::sim::{NoiseConfig, SimConfig, SimExecutor};

fn all_core_runtime(spec: CpuSpec) -> ParallelRuntime<SimExecutor> {
    ParallelRuntime::new(
        SimExecutor::new(spec, SimConfig::noiseless()),
        Box::new(DynamicScheduler),
        PerfConfig::default(),
    )
}

/// Runtime over a lease's core subset; cores whose *global* id appears in
/// `degraded` run at half speed (a background process stealing cycles).
fn lease_runtime(
    machine: &CpuSpec,
    lease: &Lease,
    degraded: &[usize],
) -> ParallelRuntime<SimExecutor> {
    let background = lease.background_for(degraded, 0.5);
    let noise = NoiseConfig { sigma: 0.0, background, ..NoiseConfig::disabled() };
    let sim_cfg = SimConfig { noise, ..SimConfig::noiseless() };
    ParallelRuntime::new(
        lease.sim_executor(machine, sim_cfg),
        Box::new(DynamicScheduler),
        PerfConfig::default(),
    )
}

/// One stream's phantom decode: every kernel of `steps` llama-style decode
/// steps through the full dynamic loop (virtual time accumulates in the
/// runtime's simulator).
fn run_decode_stream(rt: &mut ParallelRuntime<SimExecutor>, cfg: &ModelConfig, steps: usize) {
    let sys = PhantomSystem::neural_speed();
    for step in 0..steps {
        for c in decode_invocations(cfg, &sys, step) {
            rt.run(&PhantomWork::new(c));
        }
    }
}

fn assert_disjoint_covering(coord: &Coordinator) {
    let mut seen = vec![false; coord.machine().n_cores()];
    for lease in coord.leases() {
        for &core in &lease.cores() {
            assert!(!seen[core], "core {core} leased twice");
            seen[core] = true;
        }
    }
    assert!(seen.iter().all(|&s| s), "leases do not cover the machine");
}

/// Acceptance: two concurrent decode streams under the coordinator achieve
/// well over 1.5× the aggregate throughput of serializing the same two
/// streams through one engine that owns all cores. Decode kernels at this
/// scale can't use 16 cores efficiently (dispatch overhead + tiny per-core
/// shares), so disjoint halves run each stream nearly as fast as the whole
/// machine would — and there are two of them in flight.
#[test]
fn two_concurrent_streams_beat_one_serializing_engine() {
    let machine = presets::core_12900k();
    let cfg = ModelConfig::micro();
    const STEPS: usize = 32;

    // baseline: one all-core engine, streams back-to-back
    let mut serial = all_core_runtime(machine.clone());
    run_decode_stream(&mut serial, &cfg, STEPS);
    run_decode_stream(&mut serial, &cfg, STEPS);
    let t_serial = serial.exec.sim.now;

    // coordinator: disjoint topology-aware halves, concurrent virtual time
    let mut coord = Coordinator::new(machine.clone(), AllocPolicy::Balanced);
    coord.admit(0);
    coord.admit(1);
    assert_disjoint_covering(&coord);
    let leases: Vec<Lease> = coord.leases().cloned().collect();
    let mut stream_walls = Vec::new();
    for lease in &leases {
        assert_eq!(lease.n_cores(), 8);
        let mut rt = lease_runtime(&machine, lease, &[]);
        run_decode_stream(&mut rt, &cfg, STEPS);
        stream_walls.push(rt.exec.sim.now);
    }
    // streams run concurrently: aggregate wall = the slower of the two
    let t_coord = stream_walls.iter().cloned().fold(0.0f64, f64::max);

    let speedup = t_serial / t_coord;
    assert!(
        speedup > 1.5,
        "aggregate speedup {speedup:.3} (serialized {t_serial:.6}s vs coordinated {t_coord:.6}s)"
    );
    assert!(speedup < 2.5, "speedup {speedup:.3} implausible for two streams");
    // symmetric leases → symmetric streams
    let (a, b) = (stream_walls[0], stream_walls[1]);
    assert!((a - b).abs() / a.max(b) < 0.02, "stream walls diverged: {stream_walls:?}");
}

/// Acceptance: a background process stealing half of one lease's P-cores
/// mid-run is (1) visible as a throughput split between the streams,
/// (2) detected by the coordinator purely from observed per-core times,
/// and (3) answered by a rebalance that spreads the degraded cores across
/// both streams, restoring near-equal per-stream latency and improving the
/// aggregate (the slower stream's latency drops by >10%).
#[test]
fn leases_rebalance_after_mid_run_background_load_shift() {
    let machine = presets::core_12900k();
    // compute-bound probe: core strength, not the bus, decides latency
    let probe = PhantomWork::new(cost::gemm_i8_cost(256, 1024, 1024));

    let mut coord = Coordinator::new(machine.clone(), AllocPolicy::Balanced);
    coord.admit(0);
    coord.admit(1);
    let leases: Vec<Lease> = coord.leases().cloned().collect();

    // ---- phase 1: both streams healthy and symmetric ----
    let mut last_healthy = Vec::new();
    for lease in &leases {
        let mut rt = lease_runtime(&machine, lease, &[]);
        let mut last = 0.0;
        for _ in 0..10 {
            let res = rt.run(&probe);
            coord.observe(lease, KernelClass::GemmI8, &res);
            last = res.wall_secs;
        }
        last_healthy.push(last);
    }
    let (h0, h1) = (last_healthy[0], last_healthy[1]);
    assert!((h0 - h1).abs() / h0.max(h1) < 0.02, "healthy streams unequal: {last_healthy:?}");

    // ---- phase 2: background load steals 50% of stream 0's P-cores ----
    let degraded: Vec<usize> = leases[0]
        .cores()
        .into_iter()
        .filter(|&g| machine.cores[g].kind == CoreKind::Performance)
        .collect();
    assert_eq!(degraded.len(), 4);
    let mut shifted_last = Vec::new();
    for lease in &leases {
        let mut rt = lease_runtime(&machine, lease, &degraded);
        let mut last = 0.0;
        for _ in 0..12 {
            let res = rt.run(&probe);
            coord.observe(lease, KernelClass::GemmI8, &res);
            last = res.wall_secs;
        }
        shifted_last.push(last);
    }
    let pre_max = shifted_last[0].max(shifted_last[1]);
    assert!(
        shifted_last[0] / shifted_last[1] > 1.3,
        "background load not visible: {shifted_last:?}"
    );
    // the coordinator learned the degradation from timing alone
    let s = coord.strengths();
    let healthy_p = leases[1]
        .cores()
        .into_iter()
        .find(|&g| machine.cores[g].kind == CoreKind::Performance)
        .unwrap();
    for &g in &degraded {
        assert!(
            s[g] < 0.85 * s[healthy_p],
            "core {g} strength {} not degraded vs healthy {}",
            s[g],
            s[healthy_p]
        );
    }

    // ---- phase 3: rebalance spreads the degraded cores across streams ----
    let old_epoch = coord.epoch();
    coord.rebalance();
    assert!(coord.epoch() > old_epoch);
    assert_disjoint_covering(&coord);
    let new_leases: Vec<Lease> = coord.leases().cloned().collect();
    for lease in &new_leases {
        let n_degraded = lease.cores().iter().filter(|c| degraded.contains(c)).count();
        assert_eq!(n_degraded, 2, "degraded cores not spread evenly: {:?}", lease.cores());
        assert_eq!(lease.n_cores(), 8);
    }

    let mut rebalanced_last = Vec::new();
    for lease in &new_leases {
        let mut rt = lease_runtime(&machine, lease, &degraded);
        let mut last = 0.0;
        for _ in 0..12 {
            let res = rt.run(&probe);
            coord.observe(lease, KernelClass::GemmI8, &res);
            last = res.wall_secs;
        }
        rebalanced_last.push(last);
    }
    let post_max = rebalanced_last[0].max(rebalanced_last[1]);
    let post_imbalance =
        (rebalanced_last[0] - rebalanced_last[1]).abs() / post_max;
    assert!(post_imbalance < 0.05, "streams still unequal after rebalance: {rebalanced_last:?}");
    assert!(
        post_max < 0.9 * pre_max,
        "rebalance did not help: pre {pre_max:.6}s post {post_max:.6}s"
    );
    // still slower than fully healthy (the stolen cycles are really gone)
    assert!(post_max > h0.max(h1), "degradation vanished: post {post_max} healthy {h0}");
}

/// Acceptance: a lease can own cores **and** an accelerator end-to-end. On
/// a 4-P-core machine with one NPU, two streams under `Floating` affinity
/// split into "2 P-cores + NPU" and "2 P-cores"; running the paper's
/// prefill-scale GEMM through each lease's executor, the heterogeneous
/// fleet sustains well over 1.5× the aggregate rate of the best cores-only
/// split (2P/2P) of the same hardware — the NPU is real extra compute, and
/// the coordinator now hands it out like any other unit.
#[test]
fn hetero_lease_with_npu_beats_best_cores_only_split() {
    use dynpar::bench_harness::pr3::sustained_rate;
    use dynpar::coordinator::{bus_share, XpuAffinity};
    use dynpar::sim::xpu::AcceleratorSpec;

    let ultra = presets::ultra_125h();
    let p_cores = [0usize, 1, 2, 3];
    let machine = ultra.subset(&p_cores, bus_share(&ultra, &p_cores));
    let accels = vec![AcceleratorSpec::npu()];
    let mut coord = Coordinator::with_accelerators(
        machine.clone(),
        accels.clone(),
        AllocPolicy::Balanced,
        XpuAffinity::Floating,
    );
    coord.admit(0);
    coord.admit(1);
    let leases: Vec<Lease> = coord.leases().cloned().collect();
    let with_npu = leases.iter().find(|l| !l.accels().is_empty()).unwrap();
    let cores_only = leases.iter().find(|l| l.accels().is_empty()).unwrap();
    // the ROADMAP shape, literally: one stream owns "2 P-cores + the NPU"
    assert_eq!(with_npu.n_cores(), 2);
    assert_eq!(with_npu.accels(), vec![0]);
    assert_eq!(cores_only.n_cores(), 2);
    assert!(cores_only.accels().is_empty());

    // prefill-scale GEMM (the phase the paper targets with hybrid units)
    let probe = PhantomWork::new(cost::gemm_i8_cost(512, 2048, 2048));

    // heterogeneous fleet: each stream on its lease's executor; rate after
    // the device table converged
    let mut hetero_rates = Vec::new();
    let mut npu_row = Vec::new();
    for lease in &leases {
        let exec = lease.xpu_executor(&machine, &accels, SimConfig::noiseless());
        let (rate, mut exec) = sustained_rate(exec, &probe, 15);
        hetero_rates.push(rate);
        if !lease.accels().is_empty() {
            npu_row = exec.xpu.device_ratios(KernelClass::GemmI8).to_vec();
        }
    }

    // best cores-only split of the same 4 P-cores: symmetric 2P / 2P
    let mut cores_rates = Vec::new();
    for lease in &leases {
        let spec = machine.subset(&lease.cores(), bus_share(&machine, &lease.cores()));
        let exec = SimExecutor::new(spec, SimConfig::noiseless());
        cores_rates.push(sustained_rate(exec, &probe, 15).0);
    }

    // aggregate sustained rate (each stream drains its own queue)
    let hetero: f64 = hetero_rates.iter().sum();
    let cores: f64 = cores_rates.iter().sum();
    let speedup = hetero / cores;
    assert!(speedup > 1.5, "hetero {hetero:.0} vs cores-only {cores:.0} units/s (x{speedup:.2})");
    assert!(speedup < 10.0, "implausible speedup x{speedup:.2}");
    // the learned device row backs the split: the NPU out-ranks its 2 cores
    assert!(npu_row[1] > npu_row[0], "device row {npu_row:?}");
    // the cores-only stream is unaffected by its sibling's accelerator
    let idx = leases.iter().position(|l| l.accels().is_empty()).unwrap();
    let ratio = hetero_rates[idx] / cores_rates[idx];
    assert!((0.8..1.25).contains(&ratio), "cores-only stream shifted x{ratio:.2}");
}
