//! Cross-module property tests: randomized invariants over the scheduler,
//! perf table, simulator and quantization working *together*.

use dynpar::cpu::presets;
use dynpar::exec::{ParallelRuntime, PhantomWork};
use dynpar::kernels::{cost, KernelClass, WorkCost};
use dynpar::perf::{PerfConfig, PerfTable};
use dynpar::sched::{scheduler_by_name, DispatchPlan, Scheduler};
use dynpar::sim::{HybridSim, SimConfig, SimExecutor};
use dynpar::util::prop::{self, PropConfig};

fn rand_cost(rng: &mut dynpar::util::rng::Rng) -> WorkCost {
    match rng.below(3) {
        0 => cost::gemm_i8_cost(
            (1 + rng.below(64)) as usize * 16,
            (1 + rng.below(32)) as usize * 64,
            (1 + rng.below(32)) as usize * 64,
        ),
        1 => cost::gemv_q4_cost(
            (1 + rng.below(64)) as usize * 64,
            (1 + rng.below(64)) as usize * 64,
        ),
        _ => cost::attention_decode_cost(
            (1 + rng.below(32)) as usize,
            (1 + rng.below(512)) as usize,
            64,
        ),
    }
}

#[test]
fn prop_simulated_work_is_conserved() {
    // whatever the plan, every unit is executed exactly once
    prop::check_with(
        "sim_work_conserved",
        PropConfig { iters: 40, seed: 0xABCD },
        &mut |rng| {
            let spec = presets::preset_by_name(
                ["core_12900k", "ultra_125h", "homogeneous_16"][rng.below(3) as usize],
            )
            .unwrap();
            let n = spec.n_cores();
            let c = rand_cost(rng);
            let plan = match rng.below(3) {
                0 => {
                    let ratios: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 5.0)).collect();
                    scheduler_by_name("dynamic").unwrap().plan(c.units, 1, &ratios)
                }
                1 => DispatchPlan::Chunked { chunk: 1 + rng.below(64) as usize },
                _ => DispatchPlan::Guided { min_chunk: 1 + rng.below(16) as usize },
            };
            let mut sim = HybridSim::new(spec, SimConfig::noiseless());
            let res = sim.execute_plan(None, &c, &plan);
            let done: usize = res.units_done.iter().sum();
            if done != c.units {
                return Err(format!("{done} of {} units", c.units));
            }
            if !res.wall_secs.is_finite() || res.wall_secs <= 0.0 {
                return Err(format!("bad wall {}", res.wall_secs));
            }
            // per-core times bounded by wall
            for t in res.per_core_secs.iter().flatten() {
                if *t > res.wall_secs + 1e-9 {
                    return Err(format!("core time {t} > wall {}", res.wall_secs));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dynamic_never_loses_to_static_after_convergence() {
    prop::check_with(
        "dynamic_dominates",
        PropConfig { iters: 15, seed: 0xBEEF },
        &mut |rng| {
            let preset = ["core_12900k", "ultra_125h"][rng.below(2) as usize];
            let spec = presets::preset_by_name(preset).unwrap();
            let c = rand_cost(rng);
            let work = PhantomWork::new(c);
            let mut dy = ParallelRuntime::new(
                SimExecutor::new(spec.clone(), SimConfig::noiseless()),
                scheduler_by_name("dynamic").unwrap(),
                PerfConfig::default(),
            );
            let mut st = ParallelRuntime::new(
                SimExecutor::new(spec, SimConfig::noiseless()),
                scheduler_by_name("static").unwrap(),
                PerfConfig::default(),
            );
            let mut t_dy = 0.0;
            let mut t_st = 0.0;
            for _ in 0..10 {
                t_dy = dy.run(&work).wall_secs;
                t_st = st.run(&work).wall_secs;
            }
            // allow 1% slack for rounding of tiny partitions
            if t_dy <= t_st * 1.01 {
                Ok(())
            } else {
                Err(format!("dynamic {t_dy} > static {t_st} for {c:?}"))
            }
        },
    );
}

#[test]
fn prop_perf_table_converges_for_any_rates() {
    prop::check_with(
        "table_converges",
        PropConfig { iters: 30, seed: 0xF00D },
        &mut |rng| {
            let n = 2 + rng.below(14) as usize;
            let rates: Vec<f64> = (0..n).map(|_| rng.uniform(0.5, 8.0)).collect();
            let mut table = PerfTable::new(
                n,
                PerfConfig { alpha: rng.uniform(0.0, 0.6), init_ratio: 1.0 },
            );
            for _ in 0..60 {
                let pr = table.ratios(KernelClass::GemvQ4, dynpar::cpu::Isa::AvxVnni).to_vec();
                let sum: f64 = pr.iter().sum();
                let times: Vec<Option<f64>> =
                    (0..n).map(|i| Some((pr[i] / sum) / rates[i])).collect();
                table.update(KernelClass::GemvQ4, dynpar::cpu::Isa::AvxVnni, &times);
            }
            let rel = table.relative_ratios(KernelClass::GemvQ4, dynpar::cpu::Isa::AvxVnni).unwrap();
            let min_rate = rates.iter().cloned().fold(f64::INFINITY, f64::min);
            for (i, r) in rel.iter().enumerate() {
                let expect = rates[i] / min_rate;
                if (r - expect).abs() / expect > 0.02 {
                    return Err(format!("core {i}: ratio {r} vs expected {expect}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quant_kernel_roundtrip_under_partition() {
    // gemv result is identical regardless of how rows are partitioned
    use dynpar::kernels::gemv_q4::{gemv_q4_f32, gemv_q4_f32_range};
    use dynpar::quant::MatQ4;
    prop::check_with(
        "gemv_partition_invariant",
        PropConfig { iters: 25, seed: 0x9A9A },
        &mut |rng| {
            let n = (1 + rng.below(8)) as usize * 32;
            let k = (1 + rng.below(8)) as usize * 32;
            let mut wdata = vec![0.0f32; n * k];
            rng.fill_normal_f32(&mut wdata, 1.0);
            let w = MatQ4::quantize(&wdata, n, k);
            let mut x = vec![0.0f32; k];
            rng.fill_normal_f32(&mut x, 1.0);
            let whole = gemv_q4_f32(&w, &x);
            // random 3-way partition
            let a = rng.below(n as u64 + 1) as usize;
            let b = a + rng.below((n - a) as u64 + 1) as usize;
            let mut y = vec![0.0f32; n];
            gemv_q4_f32_range(&w, &x, &mut y, 0..a);
            gemv_q4_f32_range(&w, &x, &mut y, a..b);
            gemv_q4_f32_range(&w, &x, &mut y, b..n);
            if y == whole {
                Ok(())
            } else {
                Err(format!("partition ({a},{b}) changed the result"))
            }
        },
    );
}

#[test]
fn prop_virtual_time_is_monotone_and_additive() {
    prop::check_with(
        "sim_time_monotone",
        PropConfig { iters: 20, seed: 0x7777 },
        &mut |rng| {
            let spec = presets::ultra_125h();
            let mut sim = HybridSim::new(spec, SimConfig::noiseless());
            let mut prev = 0.0;
            for _ in 0..5 {
                let c = rand_cost(rng);
                let plan = DispatchPlan::Chunked { chunk: 8 };
                sim.execute_plan(None, &c, &plan);
                if sim.now < prev {
                    return Err(format!("time went backwards {prev} → {}", sim.now));
                }
                prev = sim.now;
            }
            Ok(())
        },
    );
}
