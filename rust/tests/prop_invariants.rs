//! Cross-module property tests: randomized invariants over the scheduler,
//! perf table, simulator, coordinator and quantization working *together*.

use dynpar::coordinator::{AllocPolicy, Coordinator};
use dynpar::cpu::presets;
use dynpar::cpu::CoreKind;
use dynpar::exec::{ParallelRuntime, PhantomWork};
use dynpar::kernels::{cost, KernelClass, WorkCost};
use dynpar::perf::{PerfConfig, PerfTable};
use dynpar::sched::{scheduler_by_name, DispatchPlan, Scheduler};
use dynpar::sim::{HybridSim, SimConfig, SimExecutor};
use dynpar::util::prop::{self, PropConfig};

fn rand_cost(rng: &mut dynpar::util::rng::Rng) -> WorkCost {
    match rng.below(3) {
        0 => cost::gemm_i8_cost(
            (1 + rng.below(64)) as usize * 16,
            (1 + rng.below(32)) as usize * 64,
            (1 + rng.below(32)) as usize * 64,
        ),
        1 => cost::gemv_q4_cost(
            (1 + rng.below(64)) as usize * 64,
            (1 + rng.below(64)) as usize * 64,
        ),
        _ => cost::attention_decode_cost(
            (1 + rng.below(32)) as usize,
            (1 + rng.below(512)) as usize,
            64,
        ),
    }
}

#[test]
fn prop_simulated_work_is_conserved() {
    // whatever the plan, every unit is executed exactly once
    prop::check_with(
        "sim_work_conserved",
        PropConfig { iters: 40, seed: 0xABCD },
        &mut |rng| {
            let spec = presets::preset_by_name(
                ["core_12900k", "ultra_125h", "homogeneous_16"][rng.below(3) as usize],
            )
            .unwrap();
            let n = spec.n_cores();
            let c = rand_cost(rng);
            let plan = match rng.below(3) {
                0 => {
                    let ratios: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 5.0)).collect();
                    scheduler_by_name("dynamic").unwrap().plan(c.units, 1, &ratios)
                }
                1 => DispatchPlan::Chunked { chunk: 1 + rng.below(64) as usize },
                _ => DispatchPlan::Guided { min_chunk: 1 + rng.below(16) as usize },
            };
            let mut sim = HybridSim::new(spec, SimConfig::noiseless());
            let res = sim.execute_plan(None, &c, &plan);
            let done: usize = res.units_done.iter().sum();
            if done != c.units {
                return Err(format!("{done} of {} units", c.units));
            }
            if !res.wall_secs.is_finite() || res.wall_secs <= 0.0 {
                return Err(format!("bad wall {}", res.wall_secs));
            }
            // per-core times bounded by wall
            for t in res.per_core_secs.iter().flatten() {
                if *t > res.wall_secs + 1e-9 {
                    return Err(format!("core time {t} > wall {}", res.wall_secs));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dynamic_never_loses_to_static_after_convergence() {
    prop::check_with(
        "dynamic_dominates",
        PropConfig { iters: 15, seed: 0xBEEF },
        &mut |rng| {
            let preset = ["core_12900k", "ultra_125h"][rng.below(2) as usize];
            let spec = presets::preset_by_name(preset).unwrap();
            let c = rand_cost(rng);
            let work = PhantomWork::new(c);
            let mut dy = ParallelRuntime::new(
                SimExecutor::new(spec.clone(), SimConfig::noiseless()),
                scheduler_by_name("dynamic").unwrap(),
                PerfConfig::default(),
            );
            let mut st = ParallelRuntime::new(
                SimExecutor::new(spec, SimConfig::noiseless()),
                scheduler_by_name("static").unwrap(),
                PerfConfig::default(),
            );
            let mut t_dy = 0.0;
            let mut t_st = 0.0;
            for _ in 0..10 {
                t_dy = dy.run(&work).wall_secs;
                t_st = st.run(&work).wall_secs;
            }
            // allow 1% slack for rounding of tiny partitions
            if t_dy <= t_st * 1.01 {
                Ok(())
            } else {
                Err(format!("dynamic {t_dy} > static {t_st} for {c:?}"))
            }
        },
    );
}

#[test]
fn prop_perf_table_converges_for_any_rates() {
    prop::check_with(
        "table_converges",
        PropConfig { iters: 30, seed: 0xF00D },
        &mut |rng| {
            let n = 2 + rng.below(14) as usize;
            let rates: Vec<f64> = (0..n).map(|_| rng.uniform(0.5, 8.0)).collect();
            let mut table = PerfTable::new(
                n,
                PerfConfig { alpha: rng.uniform(0.0, 0.6), init_ratio: 1.0 },
            );
            for _ in 0..60 {
                let pr = table.ratios(KernelClass::GemvQ4, dynpar::cpu::Isa::AvxVnni).to_vec();
                let sum: f64 = pr.iter().sum();
                let times: Vec<Option<f64>> =
                    (0..n).map(|i| Some((pr[i] / sum) / rates[i])).collect();
                table.update(KernelClass::GemvQ4, dynpar::cpu::Isa::AvxVnni, &times);
            }
            let rel =
                table.relative_ratios(KernelClass::GemvQ4, dynpar::cpu::Isa::AvxVnni).unwrap();
            let min_rate = rates.iter().cloned().fold(f64::INFINITY, f64::min);
            for (i, r) in rel.iter().enumerate() {
                let expect = rates[i] / min_rate;
                if (r - expect).abs() / expect > 0.02 {
                    return Err(format!("core {i}: ratio {r} vs expected {expect}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quant_kernel_roundtrip_under_partition() {
    // gemv result is identical regardless of how rows are partitioned
    use dynpar::kernels::gemv_q4::{gemv_q4_f32, gemv_q4_f32_range};
    use dynpar::quant::MatQ4;
    prop::check_with(
        "gemv_partition_invariant",
        PropConfig { iters: 25, seed: 0x9A9A },
        &mut |rng| {
            let n = (1 + rng.below(8)) as usize * 32;
            let k = (1 + rng.below(8)) as usize * 32;
            let mut wdata = vec![0.0f32; n * k];
            rng.fill_normal_f32(&mut wdata, 1.0);
            let w = MatQ4::quantize(&wdata, n, k);
            let mut x = vec![0.0f32; k];
            rng.fill_normal_f32(&mut x, 1.0);
            let whole = gemv_q4_f32(&w, &x);
            // random 3-way partition
            let a = rng.below(n as u64 + 1) as usize;
            let b = a + rng.below((n - a) as u64 + 1) as usize;
            let mut y = vec![0.0f32; n];
            gemv_q4_f32_range(&w, &x, &mut y, 0..a);
            gemv_q4_f32_range(&w, &x, &mut y, a..b);
            gemv_q4_f32_range(&w, &x, &mut y, b..n);
            if y == whole {
                Ok(())
            } else {
                Err(format!("partition ({a},{b}) changed the result"))
            }
        },
    );
}

/// Every core belongs to exactly one lease (disjoint + covering), no lease
/// is empty while streams fit on the machine, and under equal strengths
/// the Balanced policy splits each core kind across streams to within one
/// core — the coordinator's topology-aware fairness invariant.
#[test]
fn prop_coordinator_leases_disjoint_covering_topology_aware() {
    prop::check_with(
        "coordinator_lease_invariants",
        PropConfig { iters: 40, seed: 0xC0DE },
        &mut |rng| {
            let spec = presets::preset_by_name(
                ["core_12900k", "ultra_125h", "homogeneous_16"][rng.below(3) as usize],
            )
            .unwrap();
            let n = spec.n_cores();
            let k = 1 + rng.below(6) as usize;
            let policy =
                if rng.chance(0.5) { AllocPolicy::Balanced } else { AllocPolicy::Packed };
            let mut coord = Coordinator::new(spec.clone(), policy);
            for s in 0..k as u64 {
                coord.admit(s);
            }
            // randomly retire some streams (cores must flow back)
            let mut live = k;
            for s in 0..k as u64 {
                if live > 1 && rng.chance(0.3) {
                    coord.finish(s);
                    live -= 1;
                }
            }
            let mut owner = vec![None; n];
            for lease in coord.leases() {
                for &c in &lease.cores() {
                    if c >= n {
                        return Err(format!("core {c} out of range"));
                    }
                    if owner[c].is_some() {
                        return Err(format!("core {c} leased twice"));
                    }
                    owner[c] = Some(lease.stream);
                }
            }
            if owner.iter().any(|o| o.is_none()) {
                return Err(format!("not covering: {owner:?}"));
            }
            if live <= n {
                for lease in coord.leases() {
                    if lease.is_empty() {
                        return Err(format!("empty lease for stream {}", lease.stream));
                    }
                }
            }
            if policy == AllocPolicy::Balanced {
                // equal strengths → per-kind counts within 1 across streams
                for kind in [CoreKind::Performance, CoreKind::Efficiency, CoreKind::LowPower] {
                    let counts: Vec<usize> = coord
                        .leases()
                        .map(|l| l.cores().iter().filter(|&&c| spec.cores[c].kind == kind).count())
                        .collect();
                    let (mn, mx) = (
                        counts.iter().min().copied().unwrap_or(0),
                        counts.iter().max().copied().unwrap_or(0),
                    );
                    if mx - mn > 1 {
                        return Err(format!("{:?} split {counts:?} not balanced", kind.name()));
                    }
                }
            }
            Ok(())
        },
    );
}

/// A scheduler planning inside a lease sees only the lease's cores: the
/// proportional split over the sub-slice keeps every partition invariant
/// (consecutive, covering, grain-aligned) — `largest_remainder_split`'s
/// guarantees carry over to lease-local planning.
#[test]
fn prop_lease_local_plans_are_grain_aligned_partitions() {
    prop::check_with(
        "lease_local_plan_invariants",
        PropConfig { iters: 40, seed: 0x1EA5E },
        &mut |rng| {
            let spec = presets::preset_by_name(
                ["core_12900k", "ultra_125h"][rng.below(2) as usize],
            )
            .unwrap();
            let k = 1 + rng.below(4) as usize;
            let mut coord = Coordinator::new(spec, AllocPolicy::Balanced);
            for s in 0..k as u64 {
                coord.admit(s);
            }
            let stream = rng.below(k as u64);
            let lease = coord.lease(stream).unwrap().clone();
            let nw = lease.n_cores();
            if nw == 0 {
                return Err("empty lease".into());
            }
            let total = rng.below(8_192) as usize;
            let grain = 1 + rng.below(64) as usize;
            let ratios: Vec<f64> = (0..nw).map(|_| rng.uniform(0.05, 8.0)).collect();
            let plan = scheduler_by_name("dynamic").unwrap().plan(total, grain, &ratios);
            let DispatchPlan::Partitioned(rs) = plan else {
                return Err("dynamic plan not partitioned".into());
            };
            if rs.len() != nw {
                return Err(format!("plan for {} workers, lease has {nw}", rs.len()));
            }
            let mut cursor = 0;
            for r in &rs {
                if r.start != cursor || r.end < r.start {
                    return Err(format!("bad ranges {rs:?}"));
                }
                if r.start % grain != 0 && r.start != total {
                    return Err(format!("unaligned start {rs:?} grain={grain}"));
                }
                cursor = r.end;
            }
            if cursor != total {
                return Err(format!("covers {cursor} of {total}"));
            }
            Ok(())
        },
    );
}

/// Random observations never corrupt the coordinator: strengths stay
/// positive and finite, and every rebalance re-establishes the disjoint +
/// covering lease invariants.
#[test]
fn prop_coordinator_rebalance_stable_under_random_observations() {
    use dynpar::exec::RunResult;
    prop::check_with(
        "coordinator_rebalance_stability",
        PropConfig { iters: 25, seed: 0x0B5E },
        &mut |rng| {
            let spec = presets::preset_by_name(
                ["core_12900k", "ultra_125h", "homogeneous_16"][rng.below(3) as usize],
            )
            .unwrap();
            let n = spec.n_cores();
            let k = 1 + rng.below(4) as usize;
            let mut coord = Coordinator::new(spec, AllocPolicy::Balanced);
            for s in 0..k as u64 {
                coord.admit(s);
            }
            let mut stale = coord.lease(0).unwrap().clone();
            for _ in 0..12 {
                let stream = rng.below(k as u64);
                // mostly the current lease; sometimes a stale snapshot from
                // an earlier epoch (must be dropped, never mis-attributed)
                let lease = if rng.chance(0.8) {
                    coord.lease(stream).unwrap().clone()
                } else {
                    stale.clone()
                };
                let nw = lease.n_cores();
                let per_core_secs: Vec<Option<f64>> = (0..nw)
                    .map(|_| if rng.chance(0.8) { Some(rng.uniform(1e-6, 2.0)) } else { None })
                    .collect();
                let units_done: Vec<usize> =
                    (0..nw).map(|_| rng.below(10_000) as usize).collect();
                let res = RunResult {
                    wall_secs: per_core_secs.iter().flatten().cloned().fold(0.0, f64::max),
                    per_core_secs,
                    units_done,
                    bytes: 0.0,
                };
                let class = [KernelClass::GemmI8, KernelClass::GemvQ4, KernelClass::Attention]
                    [rng.below(3) as usize];
                coord.observe(&lease, class, &res);
                if rng.chance(0.2) {
                    stale = coord.lease(stream).unwrap().clone();
                }
                if rng.chance(0.3) {
                    coord.rebalance();
                }
                for s in coord.strengths() {
                    if !(s > 0.0 && s.is_finite()) {
                        return Err(format!("bad strength {s}"));
                    }
                }
                let mut seen = vec![false; n];
                for lease in coord.leases() {
                    for &c in &lease.cores() {
                        if seen[c] {
                            return Err(format!("core {c} leased twice after rebalance"));
                        }
                        seen[c] = true;
                    }
                }
                if seen.iter().any(|&s| !s) {
                    return Err("rebalance lost a core".into());
                }
            }
            Ok(())
        },
    );
}

/// Heterogeneous leasing: with accelerators enabled, any admit / finish /
/// rebalance / observe sequence keeps core leases disjoint and covering,
/// every accelerator owned by at most one lease and never by a core-less
/// one, and — under `Pinned` affinity — an accelerator's owner stable for
/// as long as that stream lives.
#[test]
fn prop_hetero_leases_stay_disjoint_covering_with_single_owner_accels() {
    use dynpar::coordinator::{ComputeUnit, XpuAffinity};
    use dynpar::exec::RunResult;
    use dynpar::sim::xpu::AcceleratorSpec;
    prop::check_with(
        "hetero_lease_invariants",
        PropConfig { iters: 30, seed: 0xACE1 },
        &mut |rng| {
            let spec = presets::preset_by_name(
                ["core_12900k", "ultra_125h", "homogeneous_16"][rng.below(3) as usize],
            )
            .unwrap();
            let n = spec.n_cores();
            let mut accels = vec![AcceleratorSpec::npu()];
            if rng.chance(0.5) {
                accels.push(AcceleratorSpec::igpu());
            }
            let n_accels = accels.len();
            let affinity =
                if rng.chance(0.5) { XpuAffinity::Pinned } else { XpuAffinity::Floating };
            let policy =
                if rng.chance(0.5) { AllocPolicy::Balanced } else { AllocPolicy::Packed };
            let mut coord = Coordinator::with_accelerators(spec, accels, policy, affinity);
            let mut live: Vec<u64> = Vec::new();
            let mut next_stream = 0u64;
            let mut prev_owner: Vec<Option<u64>> = vec![None; n_accels];
            for _ in 0..16 {
                match rng.below(4) {
                    0 => {
                        coord.admit(next_stream);
                        live.push(next_stream);
                        next_stream += 1;
                    }
                    1 if live.len() > 1 => {
                        let s = live.remove(rng.below(live.len() as u64) as usize);
                        coord.finish(s);
                    }
                    2 => coord.rebalance(),
                    _ if !live.is_empty() => {
                        let s = live[rng.below(live.len() as u64) as usize];
                        let lease = coord.lease(s).unwrap().clone();
                        let nu = lease.n_units();
                        let res = RunResult {
                            per_core_secs: (0..nu)
                                .map(|_| {
                                    if rng.chance(0.8) {
                                        Some(rng.uniform(1e-6, 2.0))
                                    } else {
                                        None
                                    }
                                })
                                .collect(),
                            wall_secs: 1.0,
                            units_done: (0..nu).map(|_| rng.below(10_000) as usize).collect(),
                            bytes: 0.0,
                        };
                        coord.observe(&lease, KernelClass::GemvQ4, &res);
                    }
                    _ => {}
                }
                if live.is_empty() {
                    continue;
                }
                // cores disjoint + covering; accel owners unique + cored
                let mut seen = vec![false; n];
                let mut owner: Vec<Option<u64>> = vec![None; n_accels];
                for lease in coord.leases() {
                    for &c in &lease.cores() {
                        if seen[c] {
                            return Err(format!("core {c} leased twice"));
                        }
                        seen[c] = true;
                    }
                    for &a in &lease.accels() {
                        if owner[a].is_some() {
                            return Err(format!("accelerator {a} leased twice"));
                        }
                        if lease.is_empty() {
                            return Err(format!("accelerator {a} on a core-less lease"));
                        }
                        owner[a] = Some(lease.stream);
                    }
                    // unit list is canonical: cores first, ascending
                    let mut sorted = lease.units.clone();
                    sorted.sort();
                    if sorted != lease.units {
                        return Err(format!("units not canonical: {:?}", lease.units));
                    }
                    if lease.units.len() != lease.strengths.len() {
                        return Err("strengths not parallel to units".into());
                    }
                    if lease.units.iter().any(|&u| matches!(u, ComputeUnit::Core(g) if g >= n)) {
                        return Err("core id out of range".into());
                    }
                }
                if seen.iter().any(|&s| !s) {
                    return Err("cores not covering".into());
                }
                if affinity == XpuAffinity::Pinned {
                    for (a, (prev, cur)) in prev_owner.iter().zip(&owner).enumerate() {
                        if let (Some(prev), Some(cur)) = (prev, cur) {
                            if live.contains(prev) && prev != cur {
                                return Err(format!(
                                    "pinned accelerator {a} moved {prev} → {cur}"
                                ));
                            }
                        }
                    }
                }
                prev_owner = owner;
            }
            Ok(())
        },
    );
}

/// Continuous batching never changes the numbers: under any admission
/// interleaving (random arrival times, prefill chunk sizes and batch
/// sizes), every request's token stream is bit-identical to a solo
/// `Engine::generate` run on the same weights.
#[test]
fn prop_continuous_batching_streams_match_solo() {
    use dynpar::engine::Engine;
    use dynpar::model::{ModelConfig, ModelWeights};
    use dynpar::server::protocol::Request;
    use dynpar::server::testing::{run_single, AdmitMode, TraceEvent};
    use dynpar::server::{BatcherOpts, LeaseBatcher};
    use std::sync::Arc;

    prop::check_with(
        "continuous_batching_solo_identical",
        PropConfig { iters: 8, seed: 0xBA7C4 },
        &mut |rng| {
            let cfg = ModelConfig::micro();
            let weights = Arc::new(ModelWeights::random_init(&cfg, rng.next_u64()));
            let spec = presets::preset_by_name(
                ["core_12900k", "ultra_125h"][rng.below(2) as usize],
            )
            .unwrap();
            let make_engine = || {
                let exec = SimExecutor::new(
                    spec.clone(),
                    SimConfig { execute_real: true, ..SimConfig::noiseless() },
                );
                Engine::new(
                    cfg.clone(),
                    Arc::clone(&weights),
                    exec,
                    scheduler_by_name("dynamic").unwrap(),
                    PerfConfig::default(),
                )
            };
            let opts = BatcherOpts {
                max_batch: 1 + rng.below(4) as usize,
                prefill_chunk: 1 + rng.below(6) as usize,
            };
            let n_req = 2 + rng.below(4) as usize;
            let mut reqs = Vec::new();
            for id in 0..n_req {
                let plen = 1 + rng.below(10) as usize;
                let prompt: Vec<u32> = (0..plen).map(|_| rng.below(128) as u32).collect();
                let max_new = 1 + rng.below(8) as usize;
                let at = rng.uniform(0.0, 2e-3);
                reqs.push((at, Request { id: id as u64, prompt, max_new_tokens: max_new }));
            }
            let script: Vec<TraceEvent> =
                reqs.iter().map(|(at, r)| TraceEvent::arrive(*at, 0, r.clone())).collect();
            let rep = run_single(
                LeaseBatcher::new(make_engine(), None, opts),
                AdmitMode::Continuous,
                64,
                script,
            );
            if !rep.all_finished() {
                return Err("not every request finished".into());
            }
            for (_, r) in &reqs {
                let mut e = make_engine();
                let mut s = e.new_session();
                let (expect, _) = e.generate(&mut s, &r.prompt, r.max_new_tokens);
                if rep.tokens_of(r.id) != &expect[..] {
                    return Err(format!(
                        "request {} diverged under interleaving (batch {}, chunk {})",
                        r.id, opts.max_batch, opts.prefill_chunk
                    ));
                }
            }
            Ok(())
        },
    );
}

/// KV-slot allocator invariants under random continuous-batching load:
/// live sessions never share a slot, slot ids stay inside the pool bound,
/// and retired slots are reused before any fresh slot is allocated (total
/// allocations never exceed the peak concurrency actually reached).
#[test]
fn prop_kv_slots_unique_and_reused() {
    use dynpar::engine::Engine;
    use dynpar::model::{ModelConfig, ModelWeights};
    use dynpar::server::protocol::Request;
    use dynpar::server::{BatcherOpts, LeaseBatcher, Pending};
    use std::sync::Arc;

    prop::check_with(
        "kv_slot_invariants",
        PropConfig { iters: 10, seed: 0x51075 },
        &mut |rng| {
            let cfg = ModelConfig::micro();
            let weights = Arc::new(ModelWeights::random_init(&cfg, rng.next_u64()));
            let exec = SimExecutor::new(
                presets::homogeneous(4),
                SimConfig { execute_real: true, ..SimConfig::noiseless() },
            );
            let engine = Engine::new(
                cfg,
                weights,
                exec,
                scheduler_by_name("dynamic").unwrap(),
                PerfConfig::default(),
            );
            let max_batch = 1 + rng.below(4) as usize;
            let opts =
                BatcherOpts { max_batch, prefill_chunk: 1 + rng.below(4) as usize };
            let mut b = LeaseBatcher::new(engine, None, opts);
            let mut rxs = Vec::new(); // keep receivers alive: no dead clients
            let mut next_id = 0u64;
            let mut peak = 0usize;
            for _ in 0..30 {
                if rng.chance(0.6) {
                    let (tx, rx) = std::sync::mpsc::channel();
                    let plen = 1 + rng.below(6) as usize;
                    let prompt: Vec<u32> = (0..plen).map(|_| rng.below(128) as u32).collect();
                    let req =
                        Request { id: next_id, prompt, max_new_tokens: 1 + rng.below(5) as usize };
                    next_id += 1;
                    if b.admit(Pending::new(req, tx)).is_ok() {
                        rxs.push(rx);
                    }
                }
                peak = peak.max(b.n_active());
                let slots = b.active_slots();
                let mut sorted = slots.clone();
                sorted.sort_unstable();
                sorted.dedup();
                if sorted.len() != slots.len() {
                    return Err(format!("KV slot double-assigned: {slots:?}"));
                }
                if slots.iter().any(|&s| s >= max_batch) {
                    return Err(format!("slot id out of pool bound: {slots:?}"));
                }
                if b.pool().allocated() > peak {
                    return Err(format!(
                        "allocated {} slots but peak concurrency was {peak} — retired slots \
                         were not reused first",
                        b.pool().allocated()
                    ));
                }
                b.step();
            }
            let mut guard = 0;
            while !b.is_idle() {
                b.step();
                guard += 1;
                if guard > 10_000 {
                    return Err("batcher failed to drain".into());
                }
            }
            Ok(())
        },
    );
}

/// The async-batch feedback loop: feeding paired per-round device/core
/// timings through `Coordinator::observe_round` converges `split_ratio`
/// to the true throughput share `R_dev / (R_cpu + R_dev)` for *any*
/// random pair of underlying rates — no one-shot profiling, occupancy
/// cancels out, and the EWMA transient dies geometrically.
#[test]
fn prop_observe_round_converges_split_ratio_to_throughput_share() {
    use dynpar::coordinator::XpuAffinity;
    use dynpar::sim::xpu::AcceleratorSpec;
    prop::check_with(
        "split_ratio_converges",
        PropConfig { iters: 25, seed: 0x5B117 },
        &mut |rng| {
            let spec = presets::preset_by_name(
                ["core_12900k", "ultra_125h", "homogeneous_16"][rng.below(3) as usize],
            )
            .unwrap();
            let mut coord = Coordinator::with_accelerators(
                spec,
                vec![AcceleratorSpec::npu()],
                AllocPolicy::Balanced,
                XpuAffinity::Floating,
            );
            coord.admit(0);
            let lease = coord.leases().next().unwrap().clone();
            // true sustained rates (tokens/s); the target share stays
            // inside the [0.05, 0.95] clamp so it is actually reachable
            let r_cpu = rng.uniform(1.0, 10.0);
            let r_dev = rng.uniform(1.0, 10.0);
            let target = r_dev / (r_cpu + r_dev);
            for _ in 0..60 {
                // rounds of random occupancy: wall = tokens / rate, so a
                // busier round carries no extra weight per token
                let n_c = 1 + rng.below(8) as usize;
                let n_d = 1 + rng.below(8) as usize;
                let folded = coord.observe_round(
                    &lease,
                    KernelClass::GemvQ4,
                    (n_c as f64 / r_cpu, n_c),
                    (n_d as f64 / r_dev, n_d),
                );
                if !folded {
                    return Err("live-lease round was rejected".into());
                }
            }
            let ratio = coord.split_ratio(&lease);
            if (ratio - target).abs() > 0.02 {
                return Err(format!(
                    "split_ratio {ratio:.4} did not converge to {target:.4} \
                     (r_cpu {r_cpu:.2}, r_dev {r_dev:.2})"
                ));
            }
            // stale lease (post-rebalance epoch) must be dropped, never folded
            coord.rebalance();
            if coord.observe_round(&lease, KernelClass::GemvQ4, (1.0, 1), (1.0, 1)) {
                return Err("stale-epoch round was folded".into());
            }
            Ok(())
        },
    );
}

/// AsyncBatch never changes the numbers: under random traces with a
/// mid-flight membership change (epoch bump → dual-batcher fleet rebuild
/// and migration), every request's token stream stays bit-identical to a
/// solo `Engine::generate` on the same weights — the CpuOnly/DeviceOnly
/// split and any cross-batcher migration only ever change timing.
#[test]
fn prop_async_batch_migration_keeps_streams_bit_identical() {
    use dynpar::coordinator::{bus_share, ExecMode, Lease, XpuAffinity};
    use dynpar::engine::Engine;
    use dynpar::model::{ModelConfig, ModelWeights};
    use dynpar::server::fleet::{DriftMonitor, EngineFactory};
    use dynpar::server::protocol::Request;
    use dynpar::server::testing::{run_fleet, TraceEvent};
    use dynpar::server::BatcherOpts;
    use dynpar::sim::xpu::{AcceleratorSpec, XpuDispatch, XpuExecutor};
    use std::sync::Arc;

    prop::check_with(
        "async_batch_migration_identical",
        PropConfig { iters: 6, seed: 0xA5B1 },
        &mut |rng| {
            let ultra = presets::ultra_125h();
            let p_cores = [0usize, 1, 2, 3];
            let spec = ultra.subset(&p_cores, bus_share(&ultra, &p_cores));
            let accels = vec![AcceleratorSpec::npu()];
            let cfg = ModelConfig::micro();
            let weights = Arc::new(ModelWeights::random_init(&cfg, rng.next_u64()));
            let factory: EngineFactory<XpuExecutor> = {
                let spec = spec.clone();
                let accels = accels.clone();
                let cfg = cfg.clone();
                let weights = Arc::clone(&weights);
                Box::new(move |lease: &Lease, dispatch: XpuDispatch| {
                    let exec = lease.xpu_executor_mode(
                        &spec,
                        &accels,
                        SimConfig { execute_real: true, ..SimConfig::noiseless() },
                        dispatch,
                    );
                    Engine::new(
                        cfg.clone(),
                        Arc::clone(&weights),
                        exec,
                        scheduler_by_name("dynamic").unwrap(),
                        PerfConfig::default(),
                    )
                })
            };
            let oracle_spec = spec.clone();
            let mut coord = Coordinator::with_accelerators(
                spec,
                accels,
                AllocPolicy::Balanced,
                XpuAffinity::Floating,
            );
            coord.set_exec_mode(ExecMode::AsyncBatch);
            let n_req = 3 + rng.below(3) as usize;
            let mut reqs = Vec::new();
            let mut trace = vec![TraceEvent::Connect { at: 0.0, stream: 0 }];
            for id in 0..n_req {
                let plen = 1 + rng.below(8) as usize;
                let prompt: Vec<u32> = (0..plen).map(|_| rng.below(128) as u32).collect();
                let req =
                    Request { id: id as u64, prompt, max_new_tokens: 2 + rng.below(6) as usize };
                trace.push(TraceEvent::arrive(rng.uniform(1e-6, 1e-3), 0, req.clone()));
                reqs.push(req);
            }
            // a second stream joins mid-trace: epoch bump, both pair
            // batchers torn down, in-flight requests migrate
            trace.push(TraceEvent::Connect { at: 5e-4, stream: 1 });
            let rep = run_fleet(
                coord,
                &factory,
                BatcherOpts {
                    max_batch: 1 + rng.below(3) as usize,
                    prefill_chunk: 1 + rng.below(5) as usize,
                },
                64,
                DriftMonitor::disabled(),
                trace,
            );
            if !rep.all_finished() {
                return Err("not every request finished".into());
            }
            if rep.rebuilds < 2 {
                return Err(format!("expected a mid-trace rebuild, saw {}", rep.rebuilds));
            }
            for r in &reqs {
                // solo oracle on the same weights: partitioning and
                // dispatch mode must never change the numbers
                let exec = SimExecutor::new(
                    oracle_spec.clone(),
                    SimConfig { execute_real: true, ..SimConfig::noiseless() },
                );
                let mut e = Engine::new(
                    cfg.clone(),
                    Arc::clone(&weights),
                    exec,
                    scheduler_by_name("dynamic").unwrap(),
                    PerfConfig::default(),
                );
                let mut s = e.new_session();
                let (expect, _) = e.generate(&mut s, &r.prompt, r.max_new_tokens);
                if rep.tokens_of(r.id) != &expect[..] {
                    return Err(format!("request {} diverged across async migration", r.id));
                }
            }
            Ok(())
        },
    );
}

/// Class-keyed strength learning: a fold tagged with one kernel class
/// (a) preserves that row's total strength mass exactly (the eq.-2
/// rescale is mass-conserving, not approximate), (b) never moves any
/// *other* class's row, and (c) keeps the allocation blend positive and
/// finite — for any machine, timings and class interleaving.
#[test]
fn prop_class_rows_fold_mass_preserving_and_independent() {
    use dynpar::exec::RunResult;
    prop::check_with(
        "class_rows_independent",
        PropConfig { iters: 30, seed: 0xC1A55 },
        &mut |rng| {
            let spec = presets::preset_by_name(
                ["core_12900k", "ultra_125h", "homogeneous_16"][rng.below(3) as usize],
            )
            .unwrap();
            let mut coord = Coordinator::new(spec, AllocPolicy::Balanced);
            coord.admit(0);
            let classes = [KernelClass::GemmI8, KernelClass::GemvQ4, KernelClass::Attention];
            for _ in 0..12 {
                let lease = coord.lease(0).unwrap().clone();
                let nw = lease.n_cores();
                let class = classes[rng.below(3) as usize];
                let res = RunResult {
                    per_core_secs: (0..nw).map(|_| Some(rng.uniform(1e-6, 1.0))).collect(),
                    wall_secs: 1.0,
                    units_done: (0..nw).map(|_| 1 + rng.below(10_000) as usize).collect(),
                    bytes: 0.0,
                };
                let before: Vec<Vec<f64>> =
                    classes.iter().map(|&c| coord.class_strengths(c)).collect();
                if !coord.observe(&lease, class, &res) {
                    return Err("valid fold rejected".into());
                }
                for (&c, old) in classes.iter().zip(&before) {
                    let now = coord.class_strengths(c);
                    if c == class {
                        // every core participated, so the whole row's
                        // mass is conserved by the rescaled EWMA
                        let (a, b): (f64, f64) = (old.iter().sum(), now.iter().sum());
                        if (a - b).abs() > 1e-9 * a {
                            return Err(format!("{c:?} mass drifted {a} -> {b}"));
                        }
                    } else if now != *old {
                        return Err(format!("{c:?} row moved by a {class:?} fold"));
                    }
                }
                for s in coord.strengths() {
                    if !(s > 0.0 && s.is_finite()) {
                        return Err(format!("bad blended strength {s}"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Phase-disaggregated serving never changes the numbers: a trace served
/// through an `ExecMode::Disaggregated` prefill/decode batcher pair —
/// every request crossing the handoff seam — produces token streams
/// bit-identical to a solo `Engine::generate` on the same weights (the
/// blended-lease oracle). Only timing may differ.
#[test]
fn prop_disaggregated_handoff_streams_match_blended_oracle() {
    use dynpar::coordinator::{ExecMode, Lease};
    use dynpar::engine::Engine;
    use dynpar::model::{ModelConfig, ModelWeights};
    use dynpar::server::fleet::{DriftMonitor, EngineFactory};
    use dynpar::server::protocol::Request;
    use dynpar::server::testing::{run_fleet, TraceEvent};
    use dynpar::server::BatcherOpts;
    use dynpar::sim::xpu::XpuDispatch;
    use std::sync::Arc;

    prop::check_with(
        "disaggregated_streams_identical",
        PropConfig { iters: 6, seed: 0xD15A6 },
        &mut |rng| {
            let spec = presets::preset_by_name(
                ["core_12900k", "ultra_125h"][rng.below(2) as usize],
            )
            .unwrap();
            let cfg = ModelConfig::micro();
            let weights = Arc::new(ModelWeights::random_init(&cfg, rng.next_u64()));
            let factory: EngineFactory<SimExecutor> = {
                let spec = spec.clone();
                let cfg = cfg.clone();
                let weights = Arc::clone(&weights);
                Box::new(move |lease: &Lease, _dispatch: XpuDispatch| {
                    let exec = lease.sim_executor(
                        &spec,
                        SimConfig { execute_real: true, ..SimConfig::noiseless() },
                    );
                    Engine::new(
                        cfg.clone(),
                        Arc::clone(&weights),
                        exec,
                        scheduler_by_name("dynamic").unwrap(),
                        PerfConfig::default(),
                    )
                })
            };
            let mut coord = Coordinator::new(spec.clone(), AllocPolicy::Balanced);
            coord.set_exec_mode(ExecMode::Disaggregated);
            let n_req = 3 + rng.below(3) as usize;
            let mut reqs = Vec::new();
            let mut trace = vec![TraceEvent::Connect { at: 0.0, stream: 0 }];
            for id in 0..n_req {
                let plen = 1 + rng.below(8) as usize;
                let prompt: Vec<u32> = (0..plen).map(|_| rng.below(128) as u32).collect();
                let req = Request {
                    id: id as u64,
                    prompt,
                    max_new_tokens: 2 + rng.below(6) as usize,
                };
                trace.push(TraceEvent::arrive(rng.uniform(1e-6, 1e-3), 0, req.clone()));
                reqs.push(req);
            }
            let rep = run_fleet(
                coord,
                &factory,
                BatcherOpts {
                    max_batch: 1 + rng.below(3) as usize,
                    prefill_chunk: 1 + rng.below(5) as usize,
                },
                64,
                DriftMonitor::disabled(),
                trace,
            );
            if !rep.all_finished() {
                return Err("not every request finished".into());
            }
            // every request must actually cross the prefill→decode seam
            if rep.handoffs < reqs.len() {
                return Err(format!("{} handoffs for {} requests", rep.handoffs, reqs.len()));
            }
            for r in &reqs {
                let exec = SimExecutor::new(
                    spec.clone(),
                    SimConfig { execute_real: true, ..SimConfig::noiseless() },
                );
                let mut e = Engine::new(
                    cfg.clone(),
                    Arc::clone(&weights),
                    exec,
                    scheduler_by_name("dynamic").unwrap(),
                    PerfConfig::default(),
                );
                let mut s = e.new_session();
                let (expect, _) = e.generate(&mut s, &r.prompt, r.max_new_tokens);
                if rep.tokens_of(r.id) != &expect[..] {
                    return Err(format!("request {} diverged across the phase handoff", r.id));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_virtual_time_is_monotone_and_additive() {
    prop::check_with(
        "sim_time_monotone",
        PropConfig { iters: 20, seed: 0x7777 },
        &mut |rng| {
            let spec = presets::ultra_125h();
            let mut sim = HybridSim::new(spec, SimConfig::noiseless());
            let mut prev = 0.0;
            for _ in 0..5 {
                let c = rand_cost(rng);
                let plan = DispatchPlan::Chunked { chunk: 8 };
                sim.execute_plan(None, &c, &plan);
                if sim.now < prev {
                    return Err(format!("time went backwards {prev} → {}", sim.now));
                }
                prev = sim.now;
            }
            Ok(())
        },
    );
}

/// Random valid model config (QK-aligned dims, even head_dim) for the
/// engine bit-identity properties below.
fn rand_model_cfg(rng: &mut dynpar::util::rng::Rng) -> dynpar::model::ModelConfig {
    use dynpar::model::ModelConfig;
    let n_heads = [1usize, 2, 4][rng.below(3) as usize];
    ModelConfig {
        name: "prop".into(),
        vocab: 32 * (2 + rng.below(3) as usize),
        d_model: 32 * n_heads,
        n_layers: 1 + rng.below(2) as usize,
        n_heads,
        d_ff: 32 * (2 + rng.below(4) as usize),
        t_max: 24,
        prefill_len: 4,
        rope_theta: 10000.0,
        rms_eps: 1e-5,
    }
}

#[test]
fn prop_fused_and_unfused_engines_are_bit_identical() {
    // the fused QKV / gate-up / batched-attention dispatch path must give
    // the same bits as the one-kernel-per-matrix path for ANY config:
    // fusion only stacks row spaces, never reorders per-row accumulation
    use dynpar::engine::Engine;
    use dynpar::model::ModelWeights;
    use std::sync::Arc;
    prop::check_with(
        "fused_bit_identical",
        PropConfig { iters: 12, seed: 0xFE11 },
        &mut |rng| {
            let cfg = rand_model_cfg(rng);
            cfg.validate()?;
            let weights = Arc::new(ModelWeights::random_init(&cfg, 100 + rng.below(1000)));
            let preset = ["core_12900k", "ultra_125h"][rng.below(2) as usize];
            let mut mk = |fused: bool| {
                let exec = SimExecutor::new(
                    presets::preset_by_name(preset).unwrap(),
                    SimConfig { execute_real: true, ..SimConfig::noiseless() },
                );
                let mut e = Engine::new(
                    cfg.clone(),
                    Arc::clone(&weights),
                    exec,
                    scheduler_by_name("dynamic").unwrap(),
                    PerfConfig::default(),
                );
                e.opts.fused = fused;
                e
            };
            let mut ef = mk(true);
            let mut eu = mk(false);
            let prompt: Vec<u32> =
                (0..1 + rng.below(6)).map(|_| rng.below(cfg.vocab as u64) as u32).collect();
            let mut sf = ef.new_session();
            let mut su = eu.new_session();
            let lf = ef.prefill(&mut sf, &prompt);
            let lu = eu.prefill(&mut su, &prompt);
            if lf != lu {
                return Err("prefill logits diverge".into());
            }
            for (a, b) in sf.kv.iter().zip(&su.kv) {
                if a.k != b.k || a.v != b.v {
                    return Err("KV caches diverge after prefill".into());
                }
            }
            let (tf, _) = ef.generate(&mut sf, &[1, 0], 4);
            let (tu, _) = eu.generate(&mut su, &[1, 0], 4);
            if tf != tu {
                return Err(format!("token streams diverge: {tf:?} vs {tu:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_arena_decode_matches_serial_oracle_bitwise() {
    // the allocation-free scratch-arena decode (fused or not, any random
    // config) must reproduce the single-threaded reference bit for bit
    use dynpar::engine::Engine;
    use dynpar::model::{decode_step_serial, ModelWeights, Session};
    use std::sync::Arc;
    prop::check_with(
        "arena_decode_vs_serial",
        PropConfig { iters: 12, seed: 0xA3EA },
        &mut |rng| {
            let cfg = rand_model_cfg(rng);
            cfg.validate()?;
            let weights = Arc::new(ModelWeights::random_init(&cfg, 500 + rng.below(1000)));
            let preset = ["core_12900k", "ultra_125h"][rng.below(2) as usize];
            let exec = SimExecutor::new(
                presets::preset_by_name(preset).unwrap(),
                SimConfig { execute_real: true, ..SimConfig::noiseless() },
            );
            let mut e = Engine::new(
                cfg.clone(),
                Arc::clone(&weights),
                exec,
                scheduler_by_name("dynamic").unwrap(),
                PerfConfig::default(),
            );
            e.opts.fused = rng.below(2) == 0;
            let mut s1 = e.new_session();
            let mut s2 = Session::new(&cfg);
            for step in 0..4 {
                let t = rng.below(cfg.vocab as u64) as u32;
                let scheduled = e.decode_step(&mut s1, t);
                let serial = decode_step_serial(&cfg, &weights, &mut s2, t);
                if scheduled != serial {
                    return Err(format!("step {step}: scheduled decode != serial oracle"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cluster_partition_is_balanced_exactly_once_and_capability_safe() {
    // the cluster tier's balanced k-way partition: every stream assigned
    // exactly once, never onto a zero-capability machine, and pairwise
    // balance within the epsilon slack band of one item
    use dynpar::cluster::partition::partition;
    prop::check_with(
        "cluster_partition_invariants",
        PropConfig { iters: 60, seed: 0xC1A5 },
        &mut |rng| {
            let n_machines = (2 + rng.below(7)) as usize;
            let n_items = (1 + rng.below(40)) as usize;
            let epsilon = rng.uniform(0.0, 0.25);
            let weights: Vec<f64> = (0..n_items).map(|_| rng.uniform(0.1, 4.0)).collect();
            let capability: Vec<f64> = (0..n_machines)
                .map(|_| if rng.below(5) == 0 { 0.0 } else { rng.uniform(0.2, 3.0) })
                .collect();
            if capability.iter().all(|&c| c <= 0.0) {
                return Ok(()); // dead cluster: partition() panics by contract
            }
            let assign = partition(&weights, &capability, epsilon);
            if assign.len() != weights.len() {
                return Err("an item went missing from the assignment".into());
            }
            let mut load = vec![0.0; n_machines];
            for (i, &m) in assign.iter().enumerate() {
                if m >= n_machines {
                    return Err(format!("item {i} placed on unknown machine {m}"));
                }
                if capability[m] <= 0.0 {
                    return Err(format!("item {i} placed on zero-capability machine {m}"));
                }
                load[m] += weights[i];
            }
            // pairwise balance: each bucket within one item (plus slack)
            // of every other, measured in normalized fill
            let total: f64 = weights.iter().sum();
            let cap_sum: f64 = capability.iter().filter(|c| **c > 0.0).sum();
            let max_w = weights.iter().cloned().fold(0.0, f64::max);
            let target =
                |m: usize| -> f64 { total.max(f64::MIN_POSITIVE) * capability[m] / cap_sum };
            for a in 0..n_machines {
                for b in 0..n_machines {
                    if capability[a] <= 0.0 || capability[b] <= 0.0 {
                        continue;
                    }
                    let fill_a = load[a] / target(a);
                    let fill_b = load[b] / target(b);
                    let bound = (1.0 + epsilon) * (fill_b + max_w / target(b)) + 1e-9;
                    if fill_a > bound {
                        return Err(format!(
                            "machine {a} fill {fill_a:.4} exceeds bound {bound:.4} vs {b}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Live strategy switching never changes the numbers: a random mixed
/// trace whose arrival mix forces the [`dynpar::router::StrategyRouter`]
/// through at least two strategy switches (chat → burst → chat) produces
/// token streams bit-identical to a solo `Engine::generate` on the same
/// weights — every switch is a fleet rebuild whose in-flight sessions
/// migrate across strategies without perturbing a single token.
#[test]
fn prop_router_switches_keep_streams_bit_identical_to_solo_oracle() {
    use dynpar::coordinator::{ExecMode, Lease};
    use dynpar::engine::Engine;
    use dynpar::model::{ModelConfig, ModelWeights};
    use dynpar::router::{RouterConfig, ServingPolicy};
    use dynpar::server::fleet::EngineFactory;
    use dynpar::server::protocol::Request;
    use dynpar::server::testing::{run_trace, TraceEvent};
    use dynpar::sim::xpu::XpuDispatch;
    use std::sync::Arc;

    prop::check_with(
        "router_switch_streams_identical",
        PropConfig { iters: 6, seed: 0x5111C4 },
        &mut |rng| {
            let spec = presets::preset_by_name(
                ["core_12900k", "ultra_125h"][rng.below(2) as usize],
            )
            .unwrap();
            let cfg = ModelConfig::micro();
            let weights = Arc::new(ModelWeights::random_init(&cfg, rng.next_u64()));
            let factory: EngineFactory<SimExecutor> = {
                let spec = spec.clone();
                let cfg = cfg.clone();
                let weights = Arc::clone(&weights);
                Box::new(move |lease: &Lease, _dispatch: XpuDispatch| {
                    let exec = lease.sim_executor(
                        &spec,
                        SimConfig { execute_real: true, ..SimConfig::noiseless() },
                    );
                    Engine::new(
                        cfg.clone(),
                        Arc::clone(&weights),
                        exec,
                        scheduler_by_name("dynamic").unwrap(),
                        PerfConfig::default(),
                    )
                })
            };
            let policy = ServingPolicy::builder()
                .max_batch(1 + rng.below(3) as usize)
                .prefill_chunk(1 + rng.below(5) as usize)
                .queue_depth(64)
                .drift(f64::INFINITY, 0)
                .router(RouterConfig { window: 4, cooldown_secs: 0.0, ..RouterConfig::default() })
                .build()
                .unwrap();
            // three window-sized waves: decode-heavy (prefill share ~0.2),
            // then prompt-heavy (~0.9), then decode-heavy again — the
            // router must cross both Schmitt thresholds
            let mut trace = vec![TraceEvent::Connect { at: 0.0, stream: 0 }];
            let mut reqs = Vec::new();
            for wave in 0..3u64 {
                for i in 0..4u64 {
                    let (plen, max_new) = if wave == 1 {
                        (12 + rng.below(6) as usize, 1 + rng.below(2) as usize)
                    } else {
                        (1 + rng.below(3) as usize, 8 + rng.below(4) as usize)
                    };
                    let prompt: Vec<u32> = (0..plen).map(|_| rng.below(128) as u32).collect();
                    let req = Request { id: wave * 4 + i, prompt, max_new_tokens: max_new };
                    let at = wave as f64 * 2e-3 + rng.uniform(1e-6, 1e-4);
                    trace.push(TraceEvent::arrive(at, 0, req.clone()));
                    reqs.push(req);
                }
            }
            let rep = run_trace(
                Coordinator::new(spec.clone(), AllocPolicy::Balanced),
                &factory,
                &policy,
                trace,
            );
            if !rep.all_finished() {
                return Err("not every request finished".into());
            }
            // the property is about switches: the trace must actually force
            // them, or the bit-identity claim is vacuous
            let modes: Vec<ExecMode> =
                rep.strategy_switches.iter().map(|(_, s)| s.mode).collect();
            if modes.len() < 2 {
                return Err(format!("router took {modes:?}, expected >= 2 switches"));
            }
            for r in &reqs {
                let exec = SimExecutor::new(
                    spec.clone(),
                    SimConfig { execute_real: true, ..SimConfig::noiseless() },
                );
                let mut e = Engine::new(
                    cfg.clone(),
                    Arc::clone(&weights),
                    exec,
                    scheduler_by_name("dynamic").unwrap(),
                    PerfConfig::default(),
                );
                let mut s = e.new_session();
                let (expect, _) = e.generate(&mut s, &r.prompt, r.max_new_tokens);
                if rep.tokens_of(r.id) != &expect[..] {
                    return Err(format!(
                        "request {} diverged across strategy switches {modes:?}",
                        r.id
                    ));
                }
            }
            Ok(())
        },
    );
}

/// The priority-classed admission queue under random interleavings of
/// push / pop / front-requeue / eviction: pop always serves the
/// highest-priority non-empty lane and never reorders within a class
/// (FIFO-per-class), eviction only ever takes the newest item of a
/// strictly lower-priority lane, and the shared depth bound is exact.
#[test]
fn prop_classed_queue_is_fifo_per_class() {
    use dynpar::server::ClassedQueue;
    use std::collections::VecDeque;

    prop::check_with(
        "classed_queue_fifo_per_class",
        PropConfig { iters: 50, seed: 0xF1F0 },
        &mut |rng| {
            let n_classes = 1 + rng.below(4) as usize;
            let depth = 2 + rng.below(14) as usize;
            let mut q: ClassedQueue<u64> = ClassedQueue::new(n_classes, depth);
            let mut model: Vec<VecDeque<u64>> = vec![VecDeque::new(); n_classes];
            let mut next_seq = 0u64;
            for _ in 0..150 {
                match rng.below(6) {
                    0..=2 => {
                        // out-of-range classes must clamp to the lowest lane
                        let class = rng.below(n_classes as u64 + 2) as usize;
                        let lane = class.min(n_classes - 1);
                        let seq = next_seq;
                        next_seq += 1;
                        match q.try_push(class, seq) {
                            Ok(()) => model[lane].push_back(seq),
                            Err(item) => {
                                if item != seq {
                                    return Err("bounced item mangled".into());
                                }
                                let total: usize = model.iter().map(|l| l.len()).sum();
                                if total < depth {
                                    return Err(format!(
                                        "bounced at {total} of {depth} queued"
                                    ));
                                }
                            }
                        }
                    }
                    3 | 4 => match q.pop() {
                        Some((c, seq)) => {
                            if model[..c].iter().any(|l| !l.is_empty()) {
                                return Err(format!(
                                    "pop served class {c} past a higher-priority lane"
                                ));
                            }
                            if model[c].pop_front() != Some(seq) {
                                return Err(format!("class {c} reordered within the lane"));
                            }
                            // the failed-admit path: requeue at the front,
                            // which must restore the exact drain order
                            if rng.chance(0.3) {
                                q.push_front(c, seq);
                                model[c].push_front(seq);
                            }
                        }
                        None => {
                            if model.iter().any(|l| !l.is_empty()) {
                                return Err("pop returned None on a non-empty queue".into());
                            }
                        }
                    },
                    _ => {
                        let class = rng.below(n_classes as u64) as usize;
                        match q.evict_lower(class) {
                            Some((c, seq)) => {
                                if c <= class {
                                    return Err(format!(
                                        "evict_lower({class}) shed equal-or-higher class {c}"
                                    ));
                                }
                                let lowest = (class + 1..n_classes)
                                    .rev()
                                    .find(|&i| !model[i].is_empty());
                                if lowest != Some(c) {
                                    return Err(format!(
                                        "evicted class {c}, lowest-priority was {lowest:?}"
                                    ));
                                }
                                if model[c].pop_back() != Some(seq) {
                                    return Err(format!(
                                        "evicted an older item of class {c}, not the newest"
                                    ));
                                }
                            }
                            None => {
                                if model[class + 1..].iter().any(|l| !l.is_empty()) {
                                    return Err(format!(
                                        "evict_lower({class}) found nothing to shed"
                                    ));
                                }
                            }
                        }
                    }
                }
                if q.len() != model.iter().map(|l| l.len()).sum::<usize>() {
                    return Err("queue length diverged from the model".into());
                }
                for (c, lane) in model.iter().enumerate() {
                    if q.len_of(c) != lane.len() {
                        return Err(format!("lane {c} length diverged"));
                    }
                }
            }
            // drain: the remaining order is exactly priority-major,
            // FIFO-per-class minor
            let drained: Vec<(usize, u64)> = std::iter::from_fn(|| q.pop()).collect();
            let expect: Vec<(usize, u64)> = model
                .iter()
                .enumerate()
                .flat_map(|(c, lane)| lane.iter().map(move |&s| (c, s)))
                .collect();
            if drained != expect {
                return Err(format!("drain order {drained:?} != model {expect:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cluster_repartition_moves_are_applicable_and_drain_dead_machines() {
    // repartition() after a capability change: the reported moves apply
    // cleanly (each names the item's true source), leave no item on a
    // dead machine, and an already-balanced cluster reports zero moves
    use dynpar::cluster::partition::{partition, repartition};
    prop::check_with(
        "cluster_repartition_invariants",
        PropConfig { iters: 60, seed: 0xD317 },
        &mut |rng| {
            let n_machines = (2 + rng.below(5)) as usize;
            let n_items = (2 + rng.below(24)) as usize;
            let epsilon = 0.05;
            let weights: Vec<f64> = (0..n_items).map(|_| rng.uniform(0.2, 2.0)).collect();
            let before: Vec<f64> = (0..n_machines).map(|_| rng.uniform(0.5, 2.0)).collect();
            let current = partition(&weights, &before, epsilon);
            // capabilities drift; some machines may die outright
            let after: Vec<f64> = before
                .iter()
                .map(|&c| if rng.below(4) == 0 { 0.0 } else { c * rng.uniform(0.05, 2.0) })
                .collect();
            if after.iter().all(|&c| c <= 0.0) {
                return Ok(());
            }
            let moves = repartition(&current, &weights, &after, epsilon);
            let mut placed = current.clone();
            for mv in &moves {
                if placed[mv.item] != mv.from {
                    return Err(format!("move {mv:?} does not match the item's source"));
                }
                if after[mv.to] <= 0.0 {
                    return Err(format!("move {mv:?} targets a dead machine"));
                }
                placed[mv.item] = mv.to;
            }
            if placed.iter().any(|&m| after[m] <= 0.0) {
                return Err("an item remained on a dead machine".into());
            }
            // no drift at all => the hysteresis must report zero moves
            let stable = repartition(&current, &weights, &before, epsilon);
            if !stable.is_empty() {
                return Err(format!("unchanged capabilities produced moves: {stable:?}"));
            }
            Ok(())
        },
    );
}
