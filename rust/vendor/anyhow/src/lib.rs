//! Minimal vendored stand-in for the `anyhow` crate.
//!
//! The sandbox image has no crates.io access, so this shim provides the
//! subset of `anyhow`'s API that the dynpar tree uses: a string-backed
//! [`Error`], the [`Result`] alias, the [`anyhow!`] / [`bail!`] macros and
//! the [`Context`] extension trait. Semantics match `anyhow` closely enough
//! that swapping the real crate back in (were a registry available) is a
//! one-line Cargo.toml change.

use std::fmt;

/// A string-backed error value.
///
/// Like `anyhow::Error`, this type deliberately does **not** implement
/// `std::error::Error` — that is what makes the blanket
/// `impl<E: std::error::Error> From<E> for Error` coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context line (rendered as `context: cause`).
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a `Result` or `Option` as it is propagated.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string (or any displayable expression).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_forms() {
        let name = "x";
        let e = anyhow!("missing {name}");
        assert_eq!(e.to_string(), "missing x");
        let e = anyhow!("{} of {}", 1, 2);
        assert_eq!(e.to_string(), "1 of 2");
        let e = anyhow!(io_err());
        assert_eq!(e.to_string(), "gone");
    }

    #[test]
    fn bail_returns_err() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("boom {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "boom 7");
    }

    #[test]
    fn context_wraps_cause() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("reading {}", "f.txt")).unwrap_err();
        assert_eq!(e.to_string(), "reading f.txt: gone");
        let o: Option<u32> = None;
        assert_eq!(o.context("empty").unwrap_err().to_string(), "empty");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::str::from_utf8(&[0xFF])?;
            Ok(s.to_string())
        }
        assert!(f().is_err());
    }
}
